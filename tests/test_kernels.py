"""Per-kernel correctness: Pallas (interpret) == ref.py oracle == numpy
storage engine, swept over shapes/dtypes + property tests (hypothesis
optional: deterministic sweeps cover the same invariants when absent)."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis.extra.numpy as hnp
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:  # optional dependency — see pyproject.toml [test]
    HAVE_HYPOTHESIS = False

from repro.kernels import ops, ref
from repro.queryproc import operators as np_ops
from repro.queryproc.expressions import Col

RNG = np.random.default_rng(42)
SHAPES = [32, 1000, 8192, 8192 * 2 + 517]
BLOCKS = [1024, 8192]


def _col(n, dtype):
    if np.dtype(dtype).kind == "f":
        return RNG.uniform(0, 50, n).astype(dtype)
    return RNG.integers(0, 50, n).astype(dtype)


# ------------------------------------------------------- predicate_bitmap
@pytest.mark.parametrize("n", SHAPES)
@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_predicate_bitmap_matches_numpy(n, dtype):
    q, d = _col(n, dtype), _col(n, dtype)
    expr = (Col("q") <= 24) & ((Col("d") > 5) | Col("q").eq(7))
    words = ops.predicate_bitmap(
        {"q": jnp.asarray(q), "d": jnp.asarray(d)},
        ops.compile_predicate(expr))
    mask = ((q <= 24) & ((d > 5) | (q == 7)))
    np.testing.assert_array_equal(np.asarray(words), np_ops.pack_bitmap(mask))


def test_predicate_bitmap_col_col():
    """Column-column Cmp (the compiler IR's Q4-style compare) evaluates
    identically in the kernel and numpy engines (one plan, two engines)."""
    a, b = _col(1000, np.float32), _col(1000, np.float32)
    expr = (Col("a") < Col("b")) & (Col("a") > 5)
    words = ops.predicate_bitmap(
        {"a": jnp.asarray(a), "b": jnp.asarray(b)},
        ops.compile_predicate(expr))
    np.testing.assert_array_equal(np.asarray(words),
                                  np_ops.pack_bitmap((a < b) & (a > 5)))


@pytest.mark.parametrize("block", BLOCKS)
def test_predicate_bitmap_blocks(block):
    n = 4 * block
    q = _col(n, np.float32)
    expr = Col("q") < 10
    words = ops.predicate_bitmap({"q": jnp.asarray(q)},
                                 ops.compile_predicate(expr), block=block)
    np.testing.assert_array_equal(np.asarray(words),
                                  np_ops.pack_bitmap(q < 10))


# ----------------------------------------------------------- bitmap_apply
@pytest.mark.parametrize("n", SHAPES)
@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_bitmap_apply(n, dtype):
    col = _col(n, dtype)
    mask = RNG.random(n) < 0.3
    words = jnp.asarray(np_ops.pack_bitmap(mask))
    masked, cnt = ops.bitmap_apply(words, jnp.asarray(col))
    np.testing.assert_allclose(np.asarray(masked), np.where(mask, col, 0))
    assert int(cnt) == int(mask.sum())


# ------------------------------------------------------------ grouped_agg
@pytest.mark.parametrize("n", SHAPES)
@pytest.mark.parametrize("groups", [1, 37, 256])
def test_grouped_agg(n, groups):
    ids = RNG.integers(0, groups, n).astype(np.int32)
    vals = RNG.normal(size=n).astype(np.float32)
    sums, counts = ops.grouped_agg(jnp.asarray(ids), jnp.asarray(vals), groups)
    want = np.zeros(groups)
    np.add.at(want, ids, vals.astype(np.float64))
    np.testing.assert_allclose(np.asarray(sums), want, atol=5e-2)
    np.testing.assert_array_equal(np.asarray(counts),
                                  np.bincount(ids, minlength=groups))


# --------------------------------------------------------- fused_scan_agg
@pytest.mark.parametrize("n", SHAPES)
@pytest.mark.parametrize("groups", [1, 37])
def test_fused_scan_agg_matches_numpy(n, groups):
    """One fused pass == predicate_bitmap -> bitmap_apply -> grouped_agg
    pipeline == the numpy storage path (filter then group)."""
    q, d = _col(n, np.float32), _col(n, np.float32)
    ids = RNG.integers(0, groups, n).astype(np.int32)
    vals = RNG.uniform(0, 10, n).astype(np.float32)
    expr = (Col("q") <= 24) & (Col("d") > 5)
    cols = {"q": jnp.asarray(q), "d": jnp.asarray(d)}
    sums, counts = ops.fused_scan_agg(cols, ops.compile_predicate(expr),
                                      jnp.asarray(ids), jnp.asarray(vals),
                                      groups, block=1024)
    mask = (q <= 24) & (d > 5)
    want = np.zeros(groups)
    np.add.at(want, ids[mask], vals[mask].astype(np.float64))
    np.testing.assert_allclose(np.asarray(sums), want, rtol=1e-4, atol=1e-2)
    np.testing.assert_array_equal(
        np.asarray(counts), np.bincount(ids[mask], minlength=groups))
    # the unfused three-kernel pipeline agrees (no materialized
    # intermediates changed the semantics)
    words = ops.predicate_bitmap(cols, ops.compile_predicate(expr), block=1024)
    masked, cnt = ops.bitmap_apply(words, jnp.asarray(vals), block=1024)
    keep_ids = np.where(mask, ids, groups)  # poison dropped rows
    s2, c2 = ops.grouped_agg(jnp.asarray(keep_ids), masked, groups + 1,
                             block=1024)
    np.testing.assert_allclose(np.asarray(sums), np.asarray(s2)[:groups],
                               rtol=1e-4, atol=1e-2)
    np.testing.assert_array_equal(np.asarray(counts),
                                  np.asarray(c2)[:groups])


def test_fused_scan_agg_no_predicate():
    ids = RNG.integers(0, 5, 3000).astype(np.int32)
    vals = RNG.uniform(0, 10, 3000).astype(np.float32)
    sums, counts = ops.fused_scan_agg({}, None, jnp.asarray(ids),
                                      jnp.asarray(vals), 5, block=1024)
    want = np.zeros(5)
    np.add.at(want, ids, vals.astype(np.float64))
    np.testing.assert_allclose(np.asarray(sums), want, rtol=1e-4, atol=1e-2)
    np.testing.assert_array_equal(np.asarray(counts), np.bincount(ids, minlength=5))


def test_fused_scan_agg_ref_oracle():
    q = _col(2048, np.float32)
    ids = RNG.integers(0, 9, 2048).astype(np.int32)
    vals = RNG.uniform(0, 10, 2048).astype(np.float32)
    pf = ops.compile_predicate(Col("q") < 30)
    cols = {"q": jnp.asarray(q)}
    s, c = ops.fused_scan_agg(cols, pf, jnp.asarray(ids), jnp.asarray(vals),
                              9, block=1024)
    rs, rc = ref.fused_scan_agg(cols, pf, jnp.asarray(ids),
                                jnp.asarray(vals), 9)
    np.testing.assert_allclose(np.asarray(s), np.asarray(rs), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(rc))


def test_grouped_agg_vs_storage_engine():
    """Kernel == the numpy grouped_agg the storage layer runs (pushback
    equivalence: either side of the network computes the same partials)."""
    from repro.queryproc.table import ColumnTable
    n = 10_000
    ids = RNG.integers(0, 16, n).astype(np.int32)
    vals = RNG.uniform(0, 10, n)
    t = ColumnTable({"g": ids, "v": vals})
    want = np_ops.grouped_agg(t, ["g"], {"s": ("sum", "v")})
    sums, _ = ops.grouped_agg(jnp.asarray(ids),
                              jnp.asarray(vals.astype(np.float32)), 16)
    np.testing.assert_allclose(np.asarray(sums)[want.cols["g"]],
                               want.cols["s"], rtol=1e-3)


# --------------------------------------------------------- hash_partition
@pytest.mark.parametrize("n", SHAPES)
@pytest.mark.parametrize("parts", [2, 4, 16])
def test_hash_partition(n, parts):
    keys = RNG.integers(0, 1 << 31, n).astype(np.int32)
    pids, hist = ops.hash_partition(jnp.asarray(keys), parts)
    want = np_ops.hash_partition_ids(keys, parts)
    np.testing.assert_array_equal(np.asarray(pids), want)
    np.testing.assert_array_equal(np.asarray(hist),
                                  np.bincount(want, minlength=parts))


# ----------------------------------------------------- fused_scan_shuffle
@pytest.mark.parametrize("n", SHAPES)
@pytest.mark.parametrize("parts", [2, 4, 16])
def test_fused_scan_shuffle_matches_numpy(n, parts):
    """One fused pass == predicate_bitmap + hash_partition + a masked
    histogram — the numpy storage path's bitmap/shuffle by-products."""
    q, d = _col(n, np.float32), _col(n, np.float32)
    keys = RNG.integers(0, 1 << 31, n).astype(np.int32)
    expr = (Col("q") <= 24) & ((Col("d") > 5) | Col("q").eq(7))
    cols = {"q": jnp.asarray(q), "d": jnp.asarray(d)}
    words, pids, hist = ops.fused_scan_shuffle(
        cols, ops.compile_predicate(expr), jnp.asarray(keys), parts,
        block=1024)
    mask = (q <= 24) & ((d > 5) | (q == 7))
    want_pid = np_ops.hash_partition_ids(keys, parts)
    np.testing.assert_array_equal(np.asarray(words),
                                  np_ops.pack_bitmap(mask))
    np.testing.assert_array_equal(np.asarray(pids), want_pid)
    np.testing.assert_array_equal(
        np.asarray(hist), np.bincount(want_pid[mask], minlength=parts))
    # the unfused two-kernel pipeline agrees on the shared outputs
    w2 = ops.predicate_bitmap(cols, ops.compile_predicate(expr), block=1024)
    p2, _ = ops.hash_partition(jnp.asarray(keys), parts, block=1024)
    np.testing.assert_array_equal(np.asarray(words), np.asarray(w2))
    np.testing.assert_array_equal(np.asarray(pids), np.asarray(p2))


def test_fused_scan_shuffle_no_predicate():
    keys = RNG.integers(0, 1 << 31, 3000).astype(np.int32)
    words, pids, hist = ops.fused_scan_shuffle({}, None, jnp.asarray(keys),
                                               5, block=1024)
    want_pid = np_ops.hash_partition_ids(keys, 5)
    np.testing.assert_array_equal(
        np.asarray(words), np_ops.pack_bitmap(np.ones(3000, bool)))
    np.testing.assert_array_equal(
        np.asarray(hist), np.bincount(want_pid, minlength=5))


def test_fused_scan_shuffle_ref_oracle():
    q = _col(2048, np.float32)
    keys = RNG.integers(0, 1 << 31, 2048).astype(np.int32)
    pf = ops.compile_predicate(Col("q") < 30)
    cols = {"q": jnp.asarray(q)}
    w, p, h = ops.fused_scan_shuffle(cols, pf, jnp.asarray(keys), 9,
                                     block=1024)
    rw, rp, rh = ref.fused_scan_shuffle(cols, pf, jnp.asarray(keys), 9)
    np.testing.assert_array_equal(np.asarray(w), np.asarray(rw))
    np.testing.assert_array_equal(np.asarray(p), np.asarray(rp))
    np.testing.assert_array_equal(np.asarray(h), np.asarray(rh))


def test_package_level_exports():
    """kernels/__init__ re-exports the op-level entry points — callers use
    one canonical import path instead of reaching into submodules."""
    import repro.kernels as K
    for name in ("predicate_bitmap", "bitmap_apply", "grouped_agg",
                 "hash_partition", "fused_scan_agg", "fused_scan_shuffle",
                 "compile_predicate", "predicate_bitmap_np"):
        assert callable(getattr(K, name)), name
        assert getattr(K, name) is getattr(ops, name), name


# -------------------------------------------------------------- property
def _check_pack_unpack(mask):
    words = np_ops.pack_bitmap(mask)
    np.testing.assert_array_equal(np_ops.unpack_bitmap(words, len(mask)), mask)
    rwords = ref.pack_bitmap(jnp.asarray(np.resize(mask, -(-len(mask) // 32) * 32)))
    got = np.asarray(rwords)
    assert np.array_equal(got[: len(words)] & _tailmask(len(mask)), words)


if HAVE_HYPOTHESIS:
    @given(mask=hnp.arrays(np.bool_, st.integers(1, 2000)))
    @settings(max_examples=30, deadline=None)
    def test_pack_unpack_roundtrip(mask):
        _check_pack_unpack(mask)


@pytest.mark.parametrize("n", [1, 31, 32, 33, 517, 2000])
@pytest.mark.parametrize("seed", [0, 1])
def test_pack_unpack_roundtrip_deterministic(n, seed):
    mask = np.random.default_rng(seed).random(n) < 0.5
    _check_pack_unpack(mask)


def _tailmask(n):
    full = -(-n // 32)
    m = np.full(full, 0xFFFFFFFF, np.uint64)
    tail = n - 32 * (full - 1)
    if tail < 32:
        m[-1] = (1 << tail) - 1
    return m.astype(np.uint32)


def _check_hash_partition_range(seed, parts):
    keys = np.random.default_rng(seed).integers(0, 1 << 31, 500).astype(np.int32)
    pids = np_ops.hash_partition_ids(keys, parts)
    assert pids.min() >= 0 and pids.max() < parts
    # permutation-invariance: same key -> same partition
    assert np.array_equal(np_ops.hash_partition_ids(keys[::-1], parts),
                          pids[::-1])


if HAVE_HYPOTHESIS:
    @given(st.integers(1, 64), st.integers(2, 64))
    @settings(max_examples=25, deadline=None)
    def test_hash_partition_range(seed, parts):
        _check_hash_partition_range(seed, parts)


@pytest.mark.parametrize("seed", [1, 17, 64])
@pytest.mark.parametrize("parts", [2, 7, 64])
def test_hash_partition_range_deterministic(seed, parts):
    _check_hash_partition_range(seed, parts)
