"""Serving engine: batched prefill+decode across model families, prompt
padding, wave batching, per-request budgets, chunked prefill."""
import jax
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import api
from repro.serve.engine import Request, ServeConfig, ServingEngine

FAMILIES = ["olmo-1b", "qwen3-14b", "mamba2-2.7b", "recurrentgemma-2b",
            "qwen2-moe-a2.7b", "whisper-small"]


@pytest.mark.parametrize("arch", FAMILIES)
def test_generate_shapes(arch):
    cfg = get_config(arch, reduced=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, ServeConfig(max_batch=2, max_len=48))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, rng.integers(4, 12))
               .astype(np.int32) for _ in range(3)]
    if cfg.family == "audio":
        pytest.skip("audio serving needs frame stubs; covered by smoke")
    outs = eng.generate(prompts, max_new=4)
    assert len(outs) == 3 and all(len(o) == 4 for o in outs)
    assert all(0 <= t < cfg.vocab_size for o in outs for t in o)


def test_per_request_max_new_honored():
    """serve() must stop each slot at ITS OWN budget — Request.max_new
    and .done were dead fields before (generate() applied one shared
    limit); this pins the per-request contract."""
    cfg = get_config("olmo-1b", reduced=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, ServeConfig(max_batch=4, max_len=64))
    rng = np.random.default_rng(2)
    budgets = [1, 3, 6, 0]
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size, 6)
                    .astype(np.int32),
                    max_new=m)
            for i, m in enumerate(budgets)]
    out = eng.serve(reqs)
    assert out is reqs
    assert [len(r.out_tokens) for r in reqs] == budgets
    assert all(r.done for r in reqs)
    # the longer slots kept decoding after the shorter ones finished, and
    # all tokens are in-vocab
    assert all(0 <= t < cfg.vocab_size for r in reqs for t in r.out_tokens)


def test_prefix_budget_matches_shared_generate():
    """A slot capped at k tokens must see exactly the first k tokens of
    the uncapped greedy stream (stopping early cannot change what was
    already decoded)."""
    cfg = get_config("olmo-1b", reduced=True)
    params = api.init_params(cfg, jax.random.PRNGKey(3))
    scfg = ServeConfig(max_batch=2, max_len=64)
    rng = np.random.default_rng(3)
    prompt = rng.integers(1, cfg.vocab_size, 8).astype(np.int32)
    full = ServingEngine(cfg, params, scfg).generate([prompt], max_new=6)[0]
    short = ServingEngine(cfg, params, scfg).generate([prompt], max_new=3)[0]
    assert short == full[:3]


def test_chunked_prefill_equivalent_and_wired():
    """The chunked-prefill branch (AdmissionPolicy.chunked +
    ServeConfig.prefill_chunk — previously never consulted) must (a)
    actually run when the policy says so, and (b) produce the same greedy
    tokens as the monolithic batched prefill: the chunk boundary changes
    how the KV cache fills, not what it holds."""
    cfg = get_config("olmo-1b", reduced=True)
    params = api.init_params(cfg, jax.random.PRNGKey(4))
    rng = np.random.default_rng(4)
    # 3 live slots > max_batch//2 = 2 -> policy says chunk; P=20 > chunk=8
    prompts = [rng.integers(1, cfg.vocab_size, 20).astype(np.int32)
               for _ in range(3)]
    mono = ServingEngine(cfg, params,
                         ServeConfig(max_batch=4, max_len=64,
                                     prefill_chunk=64))
    outs_mono = mono.generate(prompts, max_new=4)
    assert mono.chunked_prefills == 0          # P <= chunk: batched path
    chunked = ServingEngine(cfg, params,
                            ServeConfig(max_batch=4, max_len=64,
                                        prefill_chunk=8))
    outs_chunked = chunked.generate(prompts, max_new=4)
    assert chunked.chunked_prefills == 1       # the wave went chunked
    assert outs_chunked == outs_mono
    # a small wave (1 slot <= max_batch//2) stays batched even with a
    # long prompt: the policy, not just the length, gates the branch
    small = ServingEngine(cfg, params,
                          ServeConfig(max_batch=4, max_len=64,
                                      prefill_chunk=8))
    small.generate(prompts[:1], max_new=2)
    assert small.chunked_prefills == 0


def test_decode_matches_forward():
    """Greedy decode step-by-step == argmax of a full forward pass at the
    same positions (linear-cache arch, deterministic)."""
    cfg = get_config("olmo-1b", reduced=True)
    params = api.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    P = 8
    prompt = rng.integers(1, cfg.vocab_size, (1, P)).astype(np.int32)
    import jax.numpy as jnp
    batch = {"tokens": jnp.asarray(prompt)}
    logits, _, _, _ = api.forward(params, cfg, batch)
    want_next = int(jnp.argmax(logits[0, -1]))
    last, cache = api.build_decode_cache(params, cfg, batch, max_len=32)
    got_next = int(jnp.argmax(last[0]))
    assert got_next == want_next
    # one decode step then compare against forward over P+1 tokens
    tok = jnp.asarray([[got_next]], jnp.int32)
    step_logits, _ = api.decode_step(params, cfg, cache, jnp.asarray(P), tok)
    ext = jnp.concatenate([jnp.asarray(prompt), tok], axis=1)
    full_logits, _, _, _ = api.forward(params, cfg, {"tokens": ext})
    np.testing.assert_allclose(
        np.asarray(step_logits).reshape(-1),
        np.asarray(full_logits[0, -1]).reshape(-1), atol=2e-2)
