"""Serving engine: batched prefill+decode across model families, prompt
padding, wave batching."""
import jax
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import api
from repro.serve.engine import ServeConfig, ServingEngine

FAMILIES = ["olmo-1b", "qwen3-14b", "mamba2-2.7b", "recurrentgemma-2b",
            "qwen2-moe-a2.7b", "whisper-small"]


@pytest.mark.parametrize("arch", FAMILIES)
def test_generate_shapes(arch):
    cfg = get_config(arch, reduced=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, ServeConfig(max_batch=2, max_len=48))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, rng.integers(4, 12))
               .astype(np.int32) for _ in range(3)]
    if cfg.family == "audio":
        pytest.skip("audio serving needs frame stubs; covered by smoke")
    outs = eng.generate(prompts, max_new=4)
    assert len(outs) == 3 and all(len(o) == 4 for o in outs)
    assert all(0 <= t < cfg.vocab_size for o in outs for t in o)


def test_decode_matches_forward():
    """Greedy decode step-by-step == argmax of a full forward pass at the
    same positions (linear-cache arch, deterministic)."""
    cfg = get_config("olmo-1b", reduced=True)
    params = api.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    P = 8
    prompt = rng.integers(1, cfg.vocab_size, (1, P)).astype(np.int32)
    import jax.numpy as jnp
    batch = {"tokens": jnp.asarray(prompt)}
    logits, _, _, _ = api.forward(params, cfg, batch)
    want_next = int(jnp.argmax(logits[0, -1]))
    last, cache = api.build_decode_cache(params, cfg, batch, max_len=32)
    got_next = int(jnp.argmax(last[0]))
    assert got_next == want_next
    # one decode step then compare against forward over P+1 tokens
    tok = jnp.asarray([[got_next]], jnp.int32)
    step_logits, _ = api.decode_step(params, cfg, cache, jnp.asarray(P), tok)
    ext = jnp.concatenate([jnp.asarray(prompt), tok], axis=1)
    full_logits, _, _, _ = api.forward(params, cfg, {"tokens": ext})
    np.testing.assert_allclose(
        np.asarray(step_logits).reshape(-1),
        np.asarray(full_logits[0, -1]).reshape(-1), atol=2e-2)
