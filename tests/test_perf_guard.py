"""benchmarks.perf_guard: the no-comparable-prior fix.

The guard compares a suite's newest trajectory entry only against a prior
entry at the *same scale factor*. Before the fix, a newest entry with no
same-sf prior was silently skipped — CI could print "trajectory monotone"
having compared nothing. Now: prior history at other sfs only -> hard
failure; a suite's genuine first entry -> loud notice, no failure.
"""
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks import perf_guard  # noqa: E402


def _doc(history, suite="executor"):
    return {suite: {"history": history}}


def _entry(sf, speedup, **extra):
    return {"sf": sf, "total_speedup": speedup, "all_identical": True,
            **extra}


def test_same_sf_regression_fails():
    doc = _doc([_entry(2.0, 2.5), _entry(2.0, 1.0)])
    failures, notices = perf_guard.check(doc)
    assert len(failures) == 1 and "fell below" in failures[0]
    assert notices == []


def test_same_sf_within_tolerance_passes():
    doc = _doc([_entry(2.0, 2.5), _entry(2.0, 2.4)])
    failures, notices = perf_guard.check(doc)
    assert failures == [] and notices == []


def test_no_comparable_prior_fails_loudly():
    """History exists — but only at another sf. The old guard silently
    passed; now it must fail and name both scale factors."""
    doc = _doc([_entry(4.0, 2.5), _entry(4.0, 2.6), _entry(2.0, 0.1)])
    failures, _ = perf_guard.check(doc)
    assert len(failures) == 1
    assert "no comparable prior" in failures[0]
    assert "sf=2.0" in failures[0] and "4.0" in failures[0]


def test_first_ever_entry_is_notice_not_failure():
    doc = _doc([_entry(2.0, 2.5)])
    failures, notices = perf_guard.check(doc)
    assert failures == []
    assert len(notices) == 1 and "first recorded entry" in notices[0]


def test_mixed_history_compares_same_sf_only():
    """sf=4 noise must not shadow the same-sf comparison: the newest sf=2
    entry compares against the previous sf=2 entry, skipping sf=4."""
    doc = _doc([_entry(2.0, 2.0), _entry(4.0, 9.9), _entry(2.0, 1.95)])
    failures, notices = perf_guard.check(doc)
    assert failures == [] and notices == []
    doc = _doc([_entry(2.0, 2.0), _entry(4.0, 9.9), _entry(2.0, 0.5)])
    failures, _ = perf_guard.check(doc)
    assert len(failures) == 1 and "fell below" in failures[0]


def test_divergence_and_adaptive_loss_still_fail():
    doc = _doc([_entry(2.0, 2.5),
                dict(_entry(2.0, 2.6), all_identical=False)])
    failures, _ = perf_guard.check(doc)
    assert any("diverged" in f for f in failures)
    doc = _doc([_entry(2.0, 1.2), dict(_entry(2.0, 1.2),
                                       adaptive_ok=False,
                                       t_adaptive_ms=900,
                                       worse_baseline_ms=700)],
               suite="runtime")
    failures, _ = perf_guard.check(doc)
    assert any("lost to the worse forced baseline" in f for f in failures)


def test_correction_suite_convergence_flag_guarded():
    """The correction suite has no wall-clock speedup; its invariant is
    that the feedback loop shrank the estimate error."""
    doc = _doc([{"sf": 2.0, "converged": False, "err_first": 0.2,
                 "err_last": 0.4}], suite="correction")
    failures, notices = perf_guard.check(doc)
    assert len(failures) == 1 and "did not shrink" in failures[0]
    doc = _doc([{"sf": 2.0, "converged": True, "err_first": 0.2,
                 "err_last": 0.001}], suite="correction")
    failures, notices = perf_guard.check(doc)
    assert failures == [] and notices == []  # no speedup entry: no notice


def test_runtime_suite_uses_collapse_tolerance():
    # 1.2 -> 0.9 is within the runtime suite's 0.60 collapse-only band
    doc = _doc([_entry(2.0, 1.2), _entry(2.0, 0.9)], suite="runtime")
    failures, _ = perf_guard.check(doc)
    assert failures == []
    doc = _doc([_entry(2.0, 1.2), _entry(2.0, 0.5)], suite="runtime")
    failures, _ = perf_guard.check(doc)
    assert len(failures) == 1


def test_empty_and_malformed_histories_pass():
    failures, notices = perf_guard.check({"x": {"history": []},
                                          "y": {}, "z": {"history": ["?"]}})
    assert failures == [] and notices == []
