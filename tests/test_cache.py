"""Semantic pushed-result cache (core.result_cache): the byte-identity
contract across all 15 queries and all four arms — cold, warm (exact),
containment-served, and post-invalidation — plus the cost/decision
integration (a warm cache flips adaptive arbitration toward pushdown with
exact metric reconciliation), concurrent stream hammering of hot
partitions, eviction/keying/probing unit behavior, and the flag-gated
measured-signal Arbitrator port that rides along in this change."""
import dataclasses
import threading

import numpy as np
import pytest

import repro.core  # noqa: F401  (breaks the queries<->engine import cycle)
from repro.core import engine, result_cache, runtime
from repro.core.arbitrator import PUSHBACK, PUSHDOWN, Arbitrator, MeasuredLoad
from repro.core.cost import RequestCost, StorageResources, cut_score
from repro.core.executor import compile_push_plan
from repro.core.plan import PushPlan
from repro.core.result_cache import ResultCache, plan_keys
from repro.obs import metrics as om
from repro.queryproc import expressions as ex
from repro.queryproc import queries as Q
from repro.queryproc import tpch
from repro.queryproc.expressions import Col, implies
from repro.queryproc.table import ColumnTable

CAT = tpch.build_catalog(sf=0.5, num_nodes=2, rows_per_partition=2_000)
# a separate catalog for the invalidation sweep: its partitions are
# mutated (appended to) test by test, so it must never back the
# read-only identity sweeps above
MUT_CAT = tpch.build_catalog(sf=0.5, num_nodes=2, rows_per_partition=2_000)

EAGER = engine.EngineConfig(mode="eager")


@pytest.fixture(autouse=True)
def fresh_metrics():
    """Every test reads counters from its own registry."""
    prev = om.get_metrics()
    m = om.Metrics()
    om.set_metrics(m)
    yield m
    om.set_metrics(prev)


def assert_tables_identical(a: ColumnTable, b: ColumnTable, ctx=""):
    assert a.columns == b.columns, (ctx, a.columns, b.columns)
    for c in a.columns:
        x, y = a.cols[c], b.cols[c]
        assert x.dtype == y.dtype, (ctx, c, x.dtype, y.dtype)
        assert np.array_equal(x, y, equal_nan=True), (ctx, c)


def _cached_cfg(cache, mode="eager", **kw):
    return engine.EngineConfig(mode=mode, result_cache=cache, **kw)


# ------------------------------------------------ cold / warm, all queries
@pytest.mark.parametrize("qid", Q.QUERY_IDS)
def test_cold_then_warm_byte_identical(qid, fresh_metrics):
    """Cold fills, warm serves — both byte-identical to the uncached run,
    and the warm run's served-partition count reconciles with the
    ``cache.hit`` counter (probes are silent, so the counter IS the number
    of partitions the executor skipped)."""
    ref = engine.run_query(Q.build_query(qid), CAT, EAGER).result
    cache = ResultCache()
    cfg = _cached_cfg(cache)
    cold = engine.run_query(Q.build_query(qid), CAT, cfg)
    assert_tables_identical(ref, cold.result, (qid, "cold"))
    assert cold.cache_hits == 0
    hits_before = fresh_metrics.counter("cache.hit").value
    warm = engine.run_query(Q.build_query(qid), CAT, cfg)
    assert_tables_identical(ref, warm.result, (qid, "warm"))
    assert warm.cache_hits > 0
    assert fresh_metrics.counter("cache.hit").value - hits_before \
        == warm.cache_hits


def _tightened(q):
    """A variant of ``q`` whose containment-eligible plans carry the same
    predicate tightened by a data-vacuous conjunct (``col >= column min``):
    strictly tighter syntactically (the donor must be found via
    ``implies`` + re-filter), identical row set semantically (so the
    reference result is the original's)."""
    plans = {}
    n_eligible = 0
    for table, plan in q.plans.items():
        keys = plan_keys(plan)
        if keys.shape is None:
            plans[table] = plan
            continue
        col = sorted(ex.columns_of(plan.predicate))[0]
        lo = CAT.scan_table(table).stats()[col].min
        plans[table] = dataclasses.replace(
            plan, predicate=ex.And(plan.predicate,
                                   ex.Cmp(">=", Col(col), lo)))
        n_eligible += 1
    return dataclasses.replace(q, plans=plans), n_eligible


@pytest.mark.parametrize("qid", Q.QUERY_IDS)
def test_containment_served_byte_identical(qid, fresh_metrics):
    """A tighter-predicate variant is served from the original's cached
    entries via predicate implication + re-filter, byte-identical to its
    own uncached run."""
    q = Q.build_query(qid)
    variant, n_eligible = _tightened(q)
    ref = engine.run_query(variant, CAT, EAGER).result
    cache = ResultCache()
    cfg = _cached_cfg(cache)
    engine.run_query(q, CAT, cfg)  # fill with the looser originals
    got = engine.run_query(variant, CAT, cfg)
    assert_tables_identical(ref, got.result, (qid, "containment"))
    contained = fresh_metrics.counter("cache.hit.containment").value
    if n_eligible:
        assert contained > 0, (qid, "expected containment serves")
    else:
        assert contained == 0


def test_containment_refilters_a_real_delta():
    """Containment with a *non-vacuous* delta: the tighter predicate
    selects strictly fewer rows than the cached donor, and the re-filtered
    serve still matches the uncached run bit for bit."""
    loose = PushPlan("lineitem", ("l_quantity", "l_extendedprice"),
                     predicate=ex.Cmp("<", Col("l_quantity"), 40))
    tight = dataclasses.replace(
        loose, predicate=ex.And(loose.predicate,
                                ex.Cmp("<", Col("l_quantity"), 20)))
    cache = ResultCache()
    cpl_loose, cpl_tight = compile_push_plan(loose), compile_push_plan(tight)
    m = om.get_metrics()
    for part in CAT.partitions_of("lineitem"):
        res, aux = cpl_loose.execute(part.data)
        cache.put(cpl_loose, part, res, aux)
        ref, _ = cpl_tight.execute(part.data)
        served = cache.serve(cpl_tight, part)
        assert served is not None and served[2] == "containment"
        assert_tables_identical(ref, served[0], part.index)
        assert 0 < len(served[0]) < len(res)
    assert m.counter("cache.hit.containment").value \
        == len(CAT.partitions_of("lineitem"))


# ----------------------------------------------------------- invalidation
@pytest.mark.parametrize("qid", Q.QUERY_IDS)
def test_append_invalidates_never_serves_stale(qid, fresh_metrics):
    """Version-stamped invalidation: after an append to a partition the
    cache must not serve its pre-mutation rows — the post-mutation cached
    run equals a fresh uncached run on the mutated catalog."""
    q = Q.build_query(qid)
    cache = ResultCache()
    cfg = _cached_cfg(cache)
    engine.run_query(q, MUT_CAT, cfg)  # fill at the current versions
    table = sorted(q.plans)[0]
    part = MUT_CAT.tables[table][0]
    v0 = part.version
    last_row = ColumnTable({c: np.asarray(v)[-1:]
                            for c, v in part.data.cols.items()})
    MUT_CAT.append_to_partition(table, 0, last_row)
    assert part.version == v0 + 1
    ref = engine.run_query(Q.build_query(qid), MUT_CAT, EAGER).result
    got = engine.run_query(Q.build_query(qid), MUT_CAT, cfg)
    assert_tables_identical(ref, got.result, (qid, "post-append"))
    assert fresh_metrics.counter("cache.evict.stale").value >= 1
    # and the refreshed entry serves the *new* bytes afterwards
    again = engine.run_query(Q.build_query(qid), MUT_CAT, cfg)
    assert_tables_identical(ref, again.result, (qid, "refilled"))


def test_update_partition_bumps_version():
    cat = tpch.build_catalog(sf=0.1, num_nodes=1, rows_per_partition=2_000)
    part = cat.tables["nation"][0]
    v0 = part.version
    cat.update_partition("nation", 0, part.data)
    assert cat.tables["nation"][0].version == v0 + 1


# ------------------------------------------------- decision-flip (cost)
def test_warm_cache_flips_adaptive_decisions_to_pushdown(fresh_metrics):
    """The acceptance scenario: under starved storage compute
    (storage_power=0.01) cold adaptive pushes everything back; once the
    cache is warm, plan_requests collapses compute_in to 0 with the known
    entry bytes as s_out, and adaptive flips every partition to pushdown —
    served entirely from cache, byte-identical, with
    ``cache.hit == engine.cache_hits == partitions skipped``."""
    res = StorageResources(storage_power=0.01)
    q = Q.build_query("Q6")
    n_parts = len(engine.plan_requests(q, CAT))
    ref = engine.run_query(q, CAT, EAGER).result

    cache = ResultCache()
    cold = engine.run_query(Q.build_query("Q6"), CAT,
                            _cached_cfg(cache, mode="adaptive", res=res))
    assert cold.n_admitted == 0 and cold.n_pushed_back == n_parts
    assert_tables_identical(ref, cold.result, "cold-adaptive")

    fill = engine.run_query(Q.build_query("Q6"), CAT,
                            _cached_cfg(cache, mode="eager", res=res))
    assert fill.n_admitted == n_parts
    assert_tables_identical(ref, fill.result, "eager-fill")

    m = om.get_metrics()
    hits0 = m.counter("cache.hit").value
    warm = engine.run_query(Q.build_query("Q6"), CAT,
                            _cached_cfg(cache, mode="adaptive", res=res))
    assert warm.n_admitted == n_parts and warm.n_pushed_back == 0
    assert warm.cache_hits == n_parts
    assert_tables_identical(ref, warm.result, "warm-adaptive")
    assert m.counter("cache.hit").value - hits0 == n_parts
    assert m.counter("engine.cache_hits").value >= n_parts


def test_cut_score_cache_hit_zeroes_cpu_term():
    res = StorageResources()
    cost = RequestCost(s_in=1_000_000, s_out=10_000, compute_in=1_000_000)
    full = cut_score(cost, res, has_operator_work=True)
    warm = cut_score(cost, res, has_operator_work=True, cache_hit=True)
    assert warm == pytest.approx(cost.s_out / res.stream_bw)
    assert warm < full


def test_cost_hint_probe_is_silent(fresh_metrics):
    """plan-time probing must not masquerade as serving: cost_hint moves
    no counters, so cache.hit stays equal to partitions actually skipped."""
    plan = PushPlan("nation", ("n_nationkey",),
                    predicate=ex.Cmp("<", Col("n_nationkey"), 20))
    cplan = compile_push_plan(plan)
    cache = ResultCache()
    part = CAT.partitions_of("nation")[0]
    assert cache.cost_hint(cplan, part) is None  # cold probe
    res, aux = cplan.execute(part.data)
    cache.put(cplan, part, res, aux)
    m = om.get_metrics()
    before = {n: m.counter(f"cache.{n}").value
              for n in ("hit", "miss", "evict", "evict.stale")}
    hint = cache.cost_hint(cplan, part)
    assert hint is not None and hint >= 64
    after = {n: m.counter(f"cache.{n}").value
             for n in ("hit", "miss", "evict", "evict.stale")}
    assert before == after


# ------------------------------------------------------ concurrent stream
def test_concurrent_stream_hammers_hot_partitions(fresh_metrics):
    """Eight simultaneous instances of the same query share one cache from
    many worker threads: first wave races fills against serves, second
    wave is fully warm — every instance byte-identical to the solo run,
    and the warm wave's serves reconcile with its pushdown count."""
    solo = engine.run_query(Q.build_query("Q6"), CAT, EAGER).result
    cache = ResultCache()
    cfg = _cached_cfg(cache, mode="eager")
    stream = [runtime.StreamQuery(Q.build_query("Q6"), arrival=0.0)
              for _ in range(8)]
    first = runtime.run_stream(stream, CAT, cfg)
    for key, res in first.results.items():
        assert_tables_identical(solo, res, ("first", key))
    m = om.get_metrics()
    hits0 = m.counter("cache.hit").value
    second = runtime.run_stream(stream, CAT, cfg)
    for key, res in second.results.items():
        assert_tables_identical(solo, res, ("second", key))
    warm_hits = sum(pq["cache_hits"] for pq in second.per_query.values())
    assert warm_hits == second.n_pushdown  # fully warm: every request served
    assert m.counter("cache.hit").value - hits0 == warm_hits
    assert m.counter("stream.cache_hits").value >= warm_hits


# ------------------------------------------------------------ unit: keying
def test_plan_keys_eligibility():
    pred = ex.Cmp("<", Col("l_quantity"), 30)
    base = PushPlan("lineitem", ("l_quantity", "l_tax"), predicate=pred)
    assert plan_keys(base).shape is not None
    assert plan_keys(base).cacheable
    # no predicate: nothing to contain
    assert plan_keys(PushPlan("lineitem", ("l_quantity",))).shape is None
    # agg / top_k / shuffle / bitmap plans never containment-serve
    assert plan_keys(dataclasses.replace(
        base, agg=((), (("n", "count", "l_quantity"),)))).shape is None
    assert plan_keys(dataclasses.replace(
        base, top_k=("l_tax", 5, False))).shape is None
    assert plan_keys(dataclasses.replace(base, bitmap_only=True)).shape \
        is None
    # predicate column missing from the output: the re-filter cannot run
    assert plan_keys(PushPlan("lineitem", ("l_tax",),
                              predicate=pred)).shape is None
    # derive shadowing a predicate column: cached column != base column
    shadow = dataclasses.replace(
        base, derive=(("l_quantity", ("l_tax",), lambda t: t * 2.0),))
    assert plan_keys(shadow).shape is None
    # apply_bitmap output depends on an external bitmap: never cacheable
    ab = dataclasses.replace(base, apply_bitmap=True)
    assert not plan_keys(ab).cacheable


def test_plan_key_is_semantic_across_objects():
    """Two equal-semantics plan objects share one key (cross-query reuse);
    different constants key apart."""
    p1 = PushPlan("lineitem", ("l_quantity",),
                  predicate=ex.Cmp("<", Col("l_quantity"), 30),
                  derive=(("d", ("l_quantity",), lambda v: v * 2.0),))
    p2 = PushPlan("lineitem", ("l_quantity",),
                  predicate=ex.Cmp("<", Col("l_quantity"), 30),
                  derive=(("d", ("l_quantity",), lambda v: v * 2.0),))
    p3 = dataclasses.replace(
        p2, derive=(("d", ("l_quantity",), lambda v: v * 3.0),))
    assert result_cache.plan_cache_key(p1) == result_cache.plan_cache_key(p2)
    assert result_cache.plan_cache_key(p1) != result_cache.plan_cache_key(p3)


# --------------------------------------------------------- unit: eviction
def test_budget_eviction_is_hit_weighted(fresh_metrics):
    plan = PushPlan("lineitem", ("l_quantity",),
                    predicate=ex.Cmp("<", Col("l_quantity"), 100))
    cplan = compile_push_plan(plan)
    parts = CAT.partitions_of("lineitem")[:3]
    outs = [cplan.execute(p.data) for p in parts]
    one = sum(int(np.asarray(v).nbytes) for v in outs[0][0].cols.values())
    cache = ResultCache(budget_bytes=int(one * 2.5))  # room for two entries
    for p, (res, aux) in zip(parts[:2], outs[:2]):
        cache.put(cplan, p, res, aux)
    for _ in range(3):  # make partition 0 hot
        assert cache.serve(cplan, parts[0]) is not None
    cache.put(cplan, parts[2], *outs[2])
    assert cache.bytes <= cache.budget_bytes
    m = om.get_metrics()
    assert m.counter("cache.evict").value >= 1
    # the cold entry (partition 1) went first; the hot one survived
    assert cache.serve(cplan, parts[0]) is not None
    assert cache.serve(cplan, parts[1]) is None


def test_oversized_entry_is_not_cached():
    plan = PushPlan("lineitem", ("l_quantity",))
    cplan = compile_push_plan(plan)
    part = CAT.partitions_of("lineitem")[0]
    cache = ResultCache(budget_bytes=128)
    res, aux = cplan.execute(part.data)
    cache.put(cplan, part, res, aux)
    assert cache.stats()["entries"] == 0 and cache.bytes == 0


# ------------------------------------------------------- unit: implication
def test_implies_truth_table():
    x, y = Col("x"), Col("y")
    lt30, lt40 = ex.Cmp("<", x, 30), ex.Cmp("<", x, 40)
    assert implies(lt30, lt40) and not implies(lt40, lt30)
    assert implies(ex.Cmp("<=", x, 30), lt40)
    assert not implies(ex.Cmp("<=", x, 40), lt40)      # boundary strictness
    assert implies(ex.Cmp(">", x, 40), ex.Cmp(">=", x, 40))
    assert implies(ex.Cmp("==", x, 7), ex.In(x, (5, 7)))
    assert not implies(ex.Cmp("==", x, 8), ex.In(x, (5, 7)))
    assert implies(ex.In(x, (5, 7)), ex.In(x, (5, 7, 9)))
    assert not implies(ex.In(x, (5, 11)), ex.In(x, (5, 7, 9)))
    assert implies(ex.In(x, (5, 7)), ex.Cmp("<", x, 8))
    # conjunction / disjunction structure
    assert implies(ex.And(lt30, ex.Cmp(">", y, 0)), lt40)
    assert implies(lt30, ex.Or(lt40, ex.Cmp(">", y, 0)))
    assert implies(ex.Or(lt30, ex.Cmp("<", x, 20)), lt40)
    assert not implies(ex.Or(lt30, ex.Cmp("<", y, 20)), lt40)
    # different columns never imply
    assert not implies(ex.Cmp("<", y, 10), lt40)
    # vacuous (absent) predicates: None = select-everything
    assert implies(lt30, None)
    assert not implies(None, lt30)
    assert implies(None, None)


# --------------------------------------- measured-signal Arbitrator port
def test_measured_load_reads_wave_gauges(fresh_metrics):
    m = om.get_metrics()
    m.gauge("stream.node0.exec_queue").set(5.0)
    m.gauge("stream.node0.ship_queue").set(2.0)
    m.gauge("stream.cores_free").set(3.0)
    ml = MeasuredLoad()
    ml.refresh()
    assert ml.queue_depth(0, PUSHDOWN) == 5.0
    assert ml.queue_depth(0, PUSHBACK) == 2.0
    assert ml.cores_free() == 3.0
    assert ml.queue_depth(1, PUSHDOWN) is None  # never published -> fluid


def test_measured_backlog_guard_uses_gauge_depth(fresh_metrics):
    """With a deep measured exec backlog the guard admits spill to the
    slower path; with the gauge absent it falls back to the fluid queue
    (just this request), so the same request stays queued.

    pushdown is the fast path here: t_pd(no scan) ~2ms vs t_pb 8ms; with
    the fast pool saturated, spilling to pushback is worth it only if the
    fast pool's backlog exceeds 8ms of work."""
    res = StorageResources(cores=1, net_streams=1)
    cost = RequestCost(s_in=10_000_000, s_out=1_000_000,
                       compute_in=1_000_000)

    def drained_paths(measured):
        arb = Arbitrator(res, measured=measured, node_id=0)
        arb.free_pd = 0  # fast pool saturated
        return [path for _rid, path in arb.submit(0, cost)]

    assert drained_paths(None) == []  # fluid: no backlog -> hold for fast
    m = om.get_metrics()
    m.gauge("stream.node0.exec_queue").set(64.0)
    measured = MeasuredLoad()
    assert drained_paths(measured) == [PUSHBACK]  # measured backlog: spill


def test_measured_feedback_flag_is_on_by_default_and_identical():
    """The port soaked under the chaos suite (docs/faults.md) and is now
    the default; flag-off (the pure fluid reference) must still match —
    the regression pin for the flip."""
    assert engine.EngineConfig().measured_feedback is True
    q = Q.build_query("Q12")
    base = engine.run_query(q, CAT, engine.EngineConfig(mode="adaptive"))
    fluid = engine.run_query(
        Q.build_query("Q12"), CAT,
        engine.EngineConfig(mode="adaptive", measured_feedback=False))
    assert_tables_identical(base.result, fluid.result, "measured-port")


# ----------------------------------------------------- thread-safety smoke
def test_cache_threadsafe_under_direct_hammering():
    """Raw serve/put races on one hot partition from 8 threads: no
    corruption, every serve returns the exact bytes."""
    plan = PushPlan("lineitem", ("l_quantity",),
                    predicate=ex.Cmp("<", Col("l_quantity"), 50))
    cplan = compile_push_plan(plan)
    part = CAT.partitions_of("lineitem")[0]
    ref, aux = cplan.execute(part.data)
    cache = ResultCache()
    errors = []

    def worker():
        try:
            for _ in range(50):
                got = cache.serve(cplan, part)
                if got is None:
                    cache.put(cplan, part, ref, aux)
                else:
                    assert_tables_identical(ref, got[0], "hammer")
        except Exception as exc:  # pragma: no cover - failure path
            errors.append(exc)

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
