"""Fault-tolerant adaptive runtime (core.faults + the recovery contract).

The load-bearing invariant: **every query returns byte-identical results
under ANY fault schedule** — crash/timeout/straggler/transient, any
probability, any seed — because recovery is demotion to the pushback
path, which PR 4 proved byte-identical for any decision vector. On top:
the injection ledger reconciles *exactly* with the runtime's ``faults.*``
/ ``retry.*`` counters and outcome accounting, deterministic schedules
replay identically, the circuit breaker's state machine trips/probes/
closes as specified, the Arbitrator routes around tripped nodes,
``run_stream`` hedges stragglers and surfaces worker exceptions instead
of swallowing them, and ``Arbitrator.release``/``drain`` hold at the
edges (satellites).

Property tests use hypothesis when present; pinned-seed sweeps cover the
same invariants when it is absent."""
import dataclasses

import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:  # optional dependency — see pyproject.toml [test]
    HAVE_HYPOTHESIS = False

from repro.core import engine, faults, runtime
from repro.core.arbitrator import Arbitrator, MeasuredLoad, PUSHBACK, PUSHDOWN
from repro.core.cost import RequestCost, StorageResources
from repro.core.faults import (CircuitBreaker, FaultExhausted, FaultPlan,
                               FaultRule, HedgePolicy, RetryPolicy)
from repro.core.simulator import SimRequest, simulate
from repro.obs import metrics as om
from repro.queryproc import queries as Q
from repro.queryproc import tpch
from repro.queryproc.table import ColumnTable

CAT = tpch.build_catalog(sf=0.3, num_nodes=2, rows_per_partition=3_000)

# instant chaos: charged (virtual) seconds drive all retry/deadline
# arithmetic; no real sleeping in tests
FAST = RetryPolicy(sleep_scale=0.0)


@pytest.fixture(autouse=True)
def fresh_metrics():
    """Every test reconciles counters against its own registry."""
    prev = om.get_metrics()
    m = om.Metrics()
    om.set_metrics(m)
    yield m
    om.set_metrics(prev)


def assert_tables_identical(a: ColumnTable, b: ColumnTable, ctx=""):
    assert a.columns == b.columns, (ctx, a.columns, b.columns)
    for c in a.columns:
        x, y = a.cols[c], b.cols[c]
        assert x.dtype == y.dtype, (ctx, c, x.dtype, y.dtype)
        assert np.array_equal(x, y, equal_nan=True), (ctx, c)


def chaos_plan(seed: int, crash=0.25, timeout=0.15, transient=0.2,
               straggler=0.2) -> FaultPlan:
    """The four archetypes at once, unscoped — the harshest mix."""
    return FaultPlan.from_spec(
        f"crash:{crash},timeout:{timeout},transient:{transient},"
        f"straggler:{straggler}:0.001", seed=seed)


def run_with(qid: str, plan=None, retry=FAST, breaker=None,
             mode="adaptive") -> engine.QueryRun:
    cfg = engine.EngineConfig(mode=mode, faults=plan, retry=retry,
                              breaker=breaker)
    return engine.run_query(Q.build_query(qid), CAT, cfg)


# ------------------------------------------------------- FaultPlan basics
def test_spec_parsing_scopes_and_params():
    p = FaultPlan.from_spec(
        "crash:0.1, node1.pushdown.timeout:0.5, straggler:0.3:0.05,"
        "node0.lineitem.transient:1.0, pushback.crash:0.2", seed=3)
    kinds = [(r.kind, r.node, r.path, r.table, r.prob, r.param)
             for r in p.rules]
    assert kinds == [
        ("crash", None, None, None, 0.1, None),
        ("timeout", 1, "pushdown", None, 0.5, None),
        ("straggler", None, None, None, 0.3, 0.05),
        ("transient", 0, None, "lineitem", 1.0, None),
        ("crash", None, "pushback", None, 0.2, None),
    ]


@pytest.mark.parametrize("bad", ["crash", "exploded:0.5", "crash:2.0",
                                 "pushdown.krash:0.1"])
def test_spec_parsing_rejects_garbage(bad):
    with pytest.raises(ValueError):
        FaultPlan.from_spec(bad)


def test_draws_are_deterministic_and_order_independent():
    coords = [(n, p, t, k, a) for n in (0, 1) for p in (PUSHDOWN, PUSHBACK)
              for t in ("lineitem", "orders") for k in ("0x4", "7x2")
              for a in (1, 2, 3)]
    a = FaultPlan.from_spec("crash:0.4,straggler:0.3:0.01", seed=11)
    b = FaultPlan.from_spec("crash:0.4,straggler:0.3:0.01", seed=11)
    da = [a.draw(*c) for c in coords]
    db = [b.draw(*c) for c in reversed(coords)]        # any interleaving
    assert [x and x.kind for x in da] == \
        [x and x.kind for x in reversed(db)]
    assert any(x is not None for x in da)              # schedule non-empty
    # the ledger saw exactly the injected draws
    assert len(a.events()) == sum(1 for x in da if x is not None)


def test_different_seed_or_epoch_changes_the_schedule():
    coords = [(0, PUSHDOWN, "lineitem", f"{i}x1", 1) for i in range(64)]
    base = FaultPlan.from_spec("crash:0.5", seed=0)
    hits = [base.draw(*c) is not None for c in coords]
    other = FaultPlan.from_spec("crash:0.5", seed=1)
    assert hits != [other.draw(*c) is not None for c in coords]
    base2 = FaultPlan.from_spec("crash:0.5", seed=0)
    base2.bump_epoch()   # a restarted query rehearses a NEW schedule
    assert hits != [base2.draw(*c) is not None for c in coords]


def test_rule_scoping_and_max_times():
    p = FaultPlan([FaultRule("crash", 1.0, node=1, path=PUSHDOWN,
                             table="orders", max_times=2)])
    assert p.draw(0, PUSHDOWN, "orders", "k", 1) is None      # wrong node
    assert p.draw(1, PUSHBACK, "orders", "k", 1) is None      # wrong path
    assert p.draw(1, PUSHDOWN, "lineitem", "k", 1) is None    # wrong table
    assert p.draw(1, PUSHDOWN, "orders", "a", 1).kind == "crash"
    assert p.draw(1, PUSHDOWN, "orders", "b", 1).kind == "crash"
    assert p.draw(1, PUSHDOWN, "orders", "c", 1) is None      # cap reached
    assert p.counts()["crash"] == 2


def test_env_plan_roundtrip(monkeypatch):
    monkeypatch.delenv("REPRO_FAULT_SPEC", raising=False)
    assert faults.env_plan() is None
    monkeypatch.setenv("REPRO_FAULT_SPEC", "crash:0.5")
    monkeypatch.setenv("REPRO_FAULT_SEED", "9")
    p = faults.env_plan()
    assert p is not None and p.seed == 9 and p.rules[0].kind == "crash"
    assert faults.env_plan() is p      # cached: one shared event ledger
    monkeypatch.setenv("REPRO_FAULT_SEED", "10")
    assert faults.env_plan() is not p  # new key -> fresh plan


# ------------------------------------------------- RetryPolicy arithmetic
def test_backoff_is_capped_exponential_with_jitter():
    r = RetryPolicy(backoff_base_s=0.01, backoff_mult=2.0,
                    backoff_cap_s=0.03, jitter=0.5)
    assert r.backoff_s(1, 0.5) == pytest.approx(0.01)   # u=0.5 -> no jitter
    assert r.backoff_s(2, 0.5) == pytest.approx(0.02)
    assert r.backoff_s(3, 0.5) == pytest.approx(0.03)   # capped
    assert r.backoff_s(9, 0.5) == pytest.approx(0.03)
    assert r.backoff_s(1, 0.0) == pytest.approx(0.005)  # -jitter edge
    assert r.backoff_s(1, 1.0) == pytest.approx(0.015)  # +jitter edge


def test_charges_by_kind():
    r = RetryPolicy(attempt_timeout_s=0.04, detect_s=0.003)
    assert r.charge(faults.FAULT_TIMEOUT) == 0.04
    assert r.charge(faults.FAULT_CRASH) == 0.003
    assert r.charge(faults.FAULT_TRANSIENT) == 0.003


# --------------------------------------------------- HedgePolicy calibration
def test_hedge_delay_gates_and_percentile():
    h = HedgePolicy(percentile=95.0, multiplier=2.0, min_samples=4,
                    min_delay_s=0.0)
    assert h.delay_s([0.1] * 3) is None               # below min_samples
    samples = [float(i) for i in range(1, 11)]        # p95 rank -> 10.0
    assert h.delay_s(samples) == pytest.approx(20.0)
    assert HedgePolicy(fixed_delay_s=0.25).delay_s([]) == 0.25
    assert HedgePolicy(enabled=False,
                       fixed_delay_s=0.25).delay_s([]) is None
    assert HedgePolicy(min_samples=1,
                       min_delay_s=0.5).delay_s([1e-6, 1e-6]) == 0.5


# ------------------------------------------------- CircuitBreaker machine
def test_breaker_trips_probes_and_closes():
    b = CircuitBreaker(trip_after=3, probe_after=2)
    assert b.route(0, PUSHDOWN) == faults.ROUTE_ALLOW
    b.record_failure(0, PUSHDOWN)
    b.record_failure(0, PUSHDOWN)
    b.record_success(0, PUSHDOWN)          # success resets the streak
    b.record_failure(0, PUSHDOWN)
    b.record_failure(0, PUSHDOWN)
    assert b.state(0, PUSHDOWN) == faults.BREAKER_CLOSED
    b.record_failure(0, PUSHDOWN)          # 3rd consecutive: trip
    assert b.state(0, PUSHDOWN) == faults.BREAKER_OPEN
    assert b.route(0, PUSHDOWN) == faults.ROUTE_DENY
    assert b.route(0, PUSHDOWN) == faults.ROUTE_PROBE   # probe_after=2
    assert b.state(0, PUSHDOWN) == faults.BREAKER_HALF_OPEN
    assert b.route(0, PUSHDOWN) == faults.ROUTE_DENY    # one probe at a time
    b.record_success(0, PUSHDOWN)          # the probe came back healthy
    assert b.state(0, PUSHDOWN) == faults.BREAKER_CLOSED
    assert b.route(0, PUSHDOWN) == faults.ROUTE_ALLOW
    # other (node, path) circuits were never touched
    assert b.state(1, PUSHDOWN) == faults.BREAKER_CLOSED
    assert b.state(0, PUSHBACK) == faults.BREAKER_CLOSED


def test_breaker_probe_failure_reopens():
    b = CircuitBreaker(trip_after=1, probe_after=1)
    b.record_failure(0, PUSHDOWN)
    assert b.state(0, PUSHDOWN) == faults.BREAKER_OPEN
    assert b.route(0, PUSHDOWN) == faults.ROUTE_PROBE
    b.record_failure(0, PUSHDOWN)          # probe failed: straight back open
    assert b.state(0, PUSHDOWN) == faults.BREAKER_OPEN
    snap = b.snapshot()["node0.pushdown"]
    assert snap["state"] == faults.BREAKER_OPEN
    assert snap["consecutive_failures"] >= 1


# --------------------------- byte-identity under ANY fault schedule (tentpole)
@pytest.mark.parametrize("qid", Q.QUERY_IDS)
def test_chaos_byte_identity_all_queries(qid):
    clean = run_with(qid)
    assert clean.recovery is None
    chaotic = run_with(qid, plan=chaos_plan(seed=int(qid[1:])),
                       breaker=CircuitBreaker())
    assert_tables_identical(clean.result, chaotic.result, qid)
    # every admitted request either really pushed down or was demoted
    assert (sum(1 for o in chaotic.outcomes if o.path == PUSHDOWN)
            + chaotic.n_demoted) == chaotic.n_admitted


if HAVE_HYPOTHESIS:
    @settings(max_examples=12, deadline=None)
    @given(seed=st.integers(0, 2**16),
           crash=st.floats(0, 1), timeout=st.floats(0, 0.5),
           transient=st.floats(0, 0.5), straggler=st.floats(0, 0.5),
           qid=st.sampled_from(Q.QUERY_IDS))
    def test_chaos_byte_identity_property(seed, crash, timeout, transient,
                                          straggler, qid):
        prev = om.get_metrics()
        om.set_metrics(om.Metrics())
        try:
            clean = run_with(qid)
            chaotic = run_with(
                qid, plan=chaos_plan(seed, crash, timeout, transient,
                                     straggler))
            assert_tables_identical(clean.result, chaotic.result,
                                    (qid, seed))
        finally:
            om.set_metrics(prev)
else:
    @pytest.mark.parametrize("seed", range(6))
    def test_chaos_byte_identity_seed_sweep(seed):
        qid = Q.QUERY_IDS[seed % len(Q.QUERY_IDS)]
        clean = run_with(qid)
        chaotic = run_with(qid, plan=chaos_plan(seed, crash=0.2 * seed / 5,
                                                straggler=0.3))
        assert_tables_identical(clean.result, chaotic.result, (qid, seed))


def test_deterministic_schedule_replays_identically():
    a = run_with("Q5", plan=chaos_plan(seed=42))
    b = run_with("Q5", plan=chaos_plan(seed=42))
    assert a.recovery == b.recovery
    assert [dataclasses.astuple(o) for o in a.outcomes] == \
        [dataclasses.astuple(o) for o in b.outcomes]


# ------------------------------------ counters reconcile with the ledger
def test_counters_reconcile_exactly_with_injected_schedule(fresh_metrics):
    plan = chaos_plan(seed=7)
    run = run_with("Q3", plan=plan)
    counters = fresh_metrics.snapshot()["counters"]
    ledger = plan.counts()
    assert sum(ledger.values()) > 0          # the schedule really fired
    for kind in faults.FAULT_KINDS:
        assert counters.get(f"faults.{kind}", 0) == ledger[kind], kind
    # per-(node, path) failure signals == failure-kind events in the ledger
    fail_events = [e for e in plan.events()
                   if e.kind in faults.FAILURE_KINDS]
    by_np = {}
    for e in fail_events:
        k = f"faults.node{e.node}.{e.path}.failures"
        by_np[k] = by_np.get(k, 0) + 1
    for k, v in by_np.items():
        assert counters.get(k, 0) == v, k
    # split accounting matches both the ledger and the counters
    assert run.recovery["faults_injected"] == sum(ledger.values())
    assert run.recovery["retries"] == counters.get("retry.attempts", 0)
    assert run.recovery["n_demoted"] == \
        sum(1 for o in run.outcomes if o.demoted)
    demote_groups = counters.get("retry.demotions", 0)
    assert (run.recovery["n_demoted"] > 0) == (demote_groups > 0)


def test_guaranteed_crash_demotes_every_admitted_group(fresh_metrics):
    plan = FaultPlan.from_spec("pushdown.crash:1.0", seed=1)
    run = run_with("Q6", plan=plan)
    assert run.n_admitted > 0
    assert run.recovery["n_demoted"] == run.n_admitted
    assert all(o.path == PUSHBACK for o in run.outcomes)
    assert all(o.replayed for o in run.outcomes)
    # every admitted group burned its full attempt budget
    demoted = [o for o in run.outcomes if o.demoted]
    assert all(o.attempts == FAST.max_attempts for o in demoted)
    clean = run_with("Q6")
    assert_tables_identical(clean.result, run.result, "Q6 demoted")


def test_deadline_budget_exhausts_before_max_attempts():
    plan = FaultPlan.from_spec("pushdown.timeout:1.0", seed=2)
    tight = RetryPolicy(sleep_scale=0.0, max_attempts=100,
                        attempt_timeout_s=0.03, deadline_s=0.05)
    run = run_with("Q6", plan=plan, retry=tight)
    demoted = [o for o in run.outcomes if o.demoted]
    assert demoted
    # 0.03 charged per timeout + backoff: the 100-attempt cap is never the
    # binding constraint — the charged budget is
    assert all(o.attempts <= 3 for o in demoted)


def test_straggler_completes_without_retry(fresh_metrics):
    plan = FaultPlan.from_spec("straggler:1.0:0.0001", seed=3)
    run = run_with("Q6", plan=plan)
    assert run.recovery["n_demoted"] == 0
    assert run.recovery["retries"] == 0
    assert run.recovery["faults_injected"] > 0
    counters = fresh_metrics.snapshot()["counters"]
    assert counters["faults.straggler"] == plan.counts()["straggler"]
    assert all(o.path == PUSHDOWN for o in run.outcomes
               if not o.replayed and o.path == PUSHDOWN)


def test_fail_to_error_baseline_raises():
    plan = FaultPlan.from_spec("pushdown.crash:1.0", seed=4)
    strict = RetryPolicy(sleep_scale=0.0, demote_on_exhaust=False)
    with pytest.raises(FaultExhausted) as ei:
        run_with("Q6", plan=plan, retry=strict)
    assert ei.value.kind == "crash" and ei.value.path == PUSHDOWN


def test_pushback_faults_recover_via_local_replay(fresh_metrics):
    plan = FaultPlan.from_spec("pushback.crash:1.0", seed=5)
    clean = run_with("Q6", mode="no_pushdown")
    run = run_with("Q6", plan=plan, mode="no_pushdown")
    assert_tables_identical(clean.result, run.result, "pushback chaos")
    # a pushback group has no further fallback path: exhaustion replays
    # locally, never counts as a demotion
    assert run.recovery["n_demoted"] == 0
    counters = fresh_metrics.snapshot()["counters"]
    assert counters.get("retry.local_replays", 0) > 0
    assert counters.get("retry.demotions", 0) == 0


def test_fault_free_split_is_exactly_prior_behavior():
    """No plan anywhere: zero recovery accounting, no fault counters."""
    q = Q.build_query("Q12")
    reqs = engine.plan_requests(q, CAT)
    split = runtime.execute_split(
        reqs, {r.req_id: PUSHDOWN for r in reqs})
    assert split.n_demoted == 0 and split.retries == 0 \
        and split.faults_injected == 0
    assert all(o.attempts == 1 and not o.demoted and not o.hedged
               for o in split.outcomes)
    counters = om.get_metrics().snapshot()["counters"]
    assert not any(k.startswith(("faults.", "retry.", "hedge."))
                   for k in counters)


# --------------------------------------------------- chaos through the stream
def stream_of(qids, arrival=0.0):
    return [runtime.StreamQuery(Q.build_query(q), arrival) for q in qids]


def test_stream_chaos_byte_identity_and_accounting():
    qids = ["Q1", "Q3", "Q6", "Q12", "Q14"]
    cfg = engine.EngineConfig()
    clean = runtime.run_stream(stream_of(qids), CAT, cfg, time_scale=0)
    chaos_cfg = engine.EngineConfig(
        faults=chaos_plan(seed=21, crash=0.4), retry=FAST,
        breaker=CircuitBreaker())
    chaotic = runtime.run_stream(stream_of(qids), CAT, chaos_cfg,
                                 time_scale=0)
    for qid in qids:
        assert_tables_identical(clean.results[qid], chaotic.results[qid],
                                qid)
    assert chaotic.n_demoted == sum(d["n_demoted"]
                                    for d in chaotic.per_query.values())
    assert chaotic.retries >= 0 and chaotic.n_pushdown + \
        chaotic.n_pushback == clean.n_pushdown + clean.n_pushback


def test_stream_hedging_fires_and_reconciles(fresh_metrics):
    # every group straggles 5ms; a 1ms fixed hedge delay guarantees races
    cfg = engine.EngineConfig(
        faults=FaultPlan.from_spec("pushdown.straggler:1.0:0.005", seed=8),
        retry=RetryPolicy(sleep_scale=1.0),
        hedge=HedgePolicy(fixed_delay_s=0.001))
    clean = runtime.run_stream(stream_of(["Q6"]), CAT,
                               engine.EngineConfig(), time_scale=0)
    run = runtime.run_stream(stream_of(["Q6"]), CAT, cfg, time_scale=0)
    assert_tables_identical(clean.results["Q6"], run.results["Q6"],
                            "hedged")
    c = fresh_metrics.snapshot()["counters"]
    assert c.get("hedge.launched", 0) > 0
    assert c.get("hedge.won", 0) + c.get("hedge.lost", 0) == \
        c["hedge.launched"]
    assert run.hedged == c.get("hedge.won", 0)


def test_hedge_abort_token_stops_recovery_loop(fresh_metrics):
    """A lost hedge race's runner cannot be killed mid-attempt, but its
    abort token must stop it at the next attempt boundary BEFORE it draws
    more faults, charges counters, or demotes — the regression behind
    'a cancelled loser already running cannot be aborted'."""
    import threading

    q = Q.build_query("Q6")
    reqs = engine.plan_requests(q, CAT)
    sub = [r for r in reqs if r.part.node_id == 0][:2]
    cplan = runtime.compile_push_plan(sub[0].plan)
    plan = FaultPlan.from_spec("transient:1.0", seed=1)
    ev = threading.Event()
    ev.set()                     # race already resolved against this runner
    with pytest.raises(faults.HedgeAborted):
        runtime._exec_group_recovered(cplan, sub, PUSHDOWN,
                                      runtime.EXECUTOR_BATCHED, None,
                                      plan, FAST, abort=ev)
    # the aborted loser charged NOTHING: no ledger entries, no counters
    assert plan.events() == []
    c = om.get_metrics().snapshot()["counters"]
    assert not any(k.startswith(("faults.", "retry."))
                   for k in c), c


def test_hedge_loser_late_completion_no_double_count(fresh_metrics):
    """Slow-loser schedule: every pushdown group straggles 50ms (really
    slept), the hedge fires at 1ms, so every race has a loser that is
    ALREADY RUNNING when it loses and completes after the race resolved
    (run_stream joins all pools before returning, so the late completions
    are fully drained by the time we assert). Its late completion must
    not double-count shipped bytes, fault-ledger entries, or the
    exec_samples calibration stream."""
    spec = "pushdown.straggler:1.0:0.05"
    slow = RetryPolicy(sleep_scale=1.0)
    ref_cfg = engine.EngineConfig(
        faults=FaultPlan.from_spec(spec, seed=8), retry=slow,
        measured_feedback=False)
    ref = runtime.run_stream(stream_of(["Q6"]), CAT, ref_cfg, time_scale=0)
    ref_samples = om.get_metrics().snapshot()["counters"][
        "stream.exec_samples"]

    om.set_metrics(om.Metrics())         # isolate the hedged run's ledger
    hplan = FaultPlan.from_spec(spec, seed=8)
    cfg = engine.EngineConfig(faults=hplan, retry=slow,
                              hedge=HedgePolicy(fixed_delay_s=0.001),
                              measured_feedback=False)
    run = runtime.run_stream(stream_of(["Q6"]), CAT, cfg, time_scale=0)
    c = om.get_metrics().snapshot()["counters"]
    assert c.get("hedge.launched", 0) > 0          # races actually happened
    # 1. calibration: exactly one sample per group — the winners'. Losers
    #    completed (straggler really slept) but their samples are
    #    suppressed by the abort token.
    assert c["stream.exec_samples"] == ref_samples
    # 2. bytes: only the winner's results reach the accounting — the
    #    hedged run ships byte-for-byte what the unhedged one does
    assert run.real_net_bytes == ref.real_net_bytes
    assert_tables_identical(ref.results["Q6"], run.results["Q6"], "hedged")
    # 3. fault ledger: every straggler draw (winners AND losers both draw
    #    at execution start) appears in ledger and counter alike — no
    #    post-race drift between the two
    assert c.get("faults.straggler", 0) == len(hplan.events())


def test_stream_worker_exception_propagates_and_pools_shut_down():
    """Satellite: a worker exception must surface (not deadlock), close
    the query span, release every core-semaphore permit, and leave all
    pools joined."""
    import threading

    before = threading.active_count()
    cfg = engine.EngineConfig(
        faults=FaultPlan.from_spec("pushdown.crash:1.0", seed=9),
        retry=RetryPolicy(sleep_scale=0.0, demote_on_exhaust=False))
    with pytest.raises(RuntimeError) as ei:
        runtime.run_stream(stream_of(["Q6", "Q1"]), CAT, cfg, time_scale=0)
    assert isinstance(ei.value.__cause__, FaultExhausted)
    # shutdown(wait=True) joined every pool thread before the raise
    assert threading.active_count() <= before + 1


def test_stream_worker_exception_closes_query_span():
    from repro.obs import trace as T
    cfg = engine.EngineConfig(
        faults=FaultPlan.from_spec("pushdown.crash:1.0", seed=9),
        retry=RetryPolicy(sleep_scale=0.0, demote_on_exhaust=False))
    with T.tracing() as tr:
        with pytest.raises(RuntimeError):
            runtime.run_stream(stream_of(["Q6"]), CAT, cfg, time_scale=0)
    qspans = tr.find("query")
    assert qspans and all(s.dur is not None for s in qspans)
    assert any("error" in s.attrs for s in qspans)


# ------------------------------------- breaker-aware Arbitrator routing
def _cost() -> RequestCost:
    return RequestCost(s_in=8_000_000, s_out=500_000, compute_in=8_000_000)


def test_tripped_node_routes_new_decisions_to_pushback():
    b = CircuitBreaker(trip_after=1, probe_after=10**6)
    b.record_failure(0, PUSHDOWN)           # node 0's pushdown circuit open
    res = StorageResources()
    reqs = [SimRequest(i, node_id=i % 2, query_id="q", cost=_cost())
            for i in range(8)]
    sim = simulate(reqs, res, "adaptive", breaker=b)
    dec = sim.decisions()
    assert all(dec[i] == PUSHBACK for i in range(0, 8, 2))   # node 0
    assert all(dec[i] == PUSHDOWN for i in range(1, 8, 2))   # node 1 healthy


def test_probe_readmits_pushdown_on_tripped_node():
    # probe_after=1: the first denial immediately grants a half-open probe
    b = CircuitBreaker(trip_after=1, probe_after=1)
    b.record_failure(0, PUSHDOWN)
    res = StorageResources()
    reqs = [SimRequest(i, node_id=0, query_id="q", cost=_cost())
            for i in range(4)]
    sim = simulate(reqs, res, "adaptive", breaker=b)
    paths = [sim.decisions()[i] for i in range(4)]
    # the probe readmits one request down pushdown; while it is in
    # flight (half-open) the rest are denied to pushback
    assert PUSHBACK in paths and PUSHDOWN in paths


def test_forced_baselines_ignore_the_breaker():
    b = CircuitBreaker(trip_after=1, probe_after=10**6)
    b.record_failure(0, PUSHDOWN)
    reqs = [SimRequest(i, node_id=0, query_id="q", cost=_cost())
            for i in range(4)]
    sim = simulate(reqs, StorageResources(), "eager", breaker=b)
    assert all(p == PUSHDOWN for p in sim.decisions().values())


# ------------------------------- Arbitrator release/drain edges (satellite)
def test_release_on_full_pools_is_capped():
    res = StorageResources()
    arb = Arbitrator(res)
    for _ in range(5):
        arb.release(PUSHDOWN)
        arb.release(PUSHBACK)
    assert arb.free_pd == res.pd_slots       # never minted beyond the pool
    assert arb.free_pb == res.pb_slots
    # the minted-slot overdraft would have admitted more than the pool
    for i in range(res.pd_slots + res.pb_slots + 4):
        arb.submit(i, _cost())
    assert arb.admitted <= res.pd_slots
    assert arb.pushed_back <= res.pb_slots


def test_drain_mixed_tripped_and_healthy_nodes():
    b = CircuitBreaker(trip_after=1, probe_after=10**6)
    b.record_failure(3, PUSHDOWN)
    res = StorageResources()
    sick = Arbitrator(res, node_id=3, breaker=b)
    healthy = Arbitrator(res, node_id=4, breaker=b)
    sick_paths = [p for i in range(4)
                  for _r, p in sick.submit(i, _cost())]
    healthy_paths = [p for i in range(4)
                     for _r, p in healthy.submit(100 + i, _cost())]
    assert set(sick_paths) == {PUSHBACK}
    assert set(healthy_paths) == {PUSHDOWN}


def test_drain_pa_respects_tripped_breaker():
    b = CircuitBreaker(trip_after=1, probe_after=10**6)
    b.record_failure(0, PUSHDOWN)
    arb = Arbitrator(StorageResources(), pa_aware=True, node_id=0,
                     breaker=b)
    paths = [p for i in range(4) for _r, p in arb.submit(i, _cost())]
    assert set(paths) == {PUSHBACK}


class _FlakyMeasured(MeasuredLoad):
    """Publishes a depth on the first read, then goes dark (a poller
    losing its feed mid-stream)."""

    def __init__(self):
        super().__init__()
        self._reads = 0

    def queue_depth(self, node_id, path):
        self._reads += 1
        return 64.0 if self._reads == 1 else None

    def refresh(self):
        pass


def test_spill_ok_survives_measured_going_dark_mid_stream():
    res = StorageResources(cores=1, net_streams=1)
    cost = RequestCost(s_in=10_000_000, s_out=1_000_000,
                       compute_in=1_000_000)
    arb = Arbitrator(res, measured=_FlakyMeasured(), node_id=0)
    arb.free_pd = 0
    # first submit: measured depth 64 -> spill admitted to pushback
    assert [p for _r, p in arb.submit(0, cost)] == [PUSHBACK]
    # signal lost: falls back to the fluid queue (len==1 here -> no spill,
    # the request just waits) — no crash, no stale-signal reuse
    assert arb.submit(1, cost) == []
    assert len(arb.queue) == 1


def test_release_drains_after_breaker_recovery():
    b = CircuitBreaker(trip_after=1, probe_after=10**6)
    b.record_failure(0, PUSHDOWN)
    res = StorageResources(cores=1, net_streams=1)  # 1 slot per pool
    arb = Arbitrator(res, node_id=0, breaker=b, backlog_guard=False)
    # with a single full-bandwidth stream, pushdown only wins for a very
    # selective request: big s_in, tiny s_out
    cost = RequestCost(s_in=50_000_000, s_out=500_000, compute_in=8_000_000)
    first = [p for _r, p in arb.submit(0, cost)]
    assert first == [PUSHBACK]                      # denied -> pushback
    arb.submit(1, cost)                             # pb pool now full: queued
    assert len(arb.queue) == 1
    b.record_success(0, PUSHDOWN)                   # circuit closes
    assert [p for _r, p in arb.release(PUSHBACK)] == [PUSHDOWN]
