"""Multi-process storage tier (distributed.workers): wire codec, plan
marshalling, the in-process oracle contract (byte-identity across tiers
for any decision vector and any fault schedule), live load signals, and
real process-failure recovery through the PR-8 retry/demote machinery."""
import os
import socket
import time

import numpy as np
import pytest

from repro.core import engine, runtime
from repro.core.arbitrator import PUSHBACK, PUSHDOWN
from repro.core.executor import EXECUTOR_BATCHED, compile_push_plan
from repro.core.faults import FaultExhausted, RetryPolicy, WorkerFault
from repro.core.plan import execute_push_plan
from repro.distributed import workers as W
from repro.obs import metrics as om
from repro.obs import trace as T
from repro.queryproc import queries as Q
from repro.queryproc import tpch
from repro.queryproc.table import ColumnTable

CAT = tpch.build_catalog(sf=0.3, num_nodes=2, rows_per_partition=3_000)
FAST = RetryPolicy(sleep_scale=0.0)


@pytest.fixture(autouse=True)
def fresh_metrics():
    """Every test reconciles counters/gauges against its own registry."""
    prev = om.get_metrics()
    m = om.Metrics()
    om.set_metrics(m)
    yield m
    om.set_metrics(prev)


@pytest.fixture(scope="module")
def pool():
    """One shared pool over CAT for the non-destructive tests (the chaos
    tests fork their own so a killed worker never leaks across tests)."""
    p = W.WorkerPool(CAT, pd_slots=2)
    yield p
    p.close()


def assert_tables_identical(a: ColumnTable, b: ColumnTable, ctx=""):
    assert a.columns == b.columns, (ctx, a.columns, b.columns)
    for c in a.columns:
        x, y = a.cols[c], b.cols[c]
        assert x.dtype == y.dtype, (ctx, c, x.dtype, y.dtype)
        assert np.array_equal(x, y, equal_nan=True), (ctx, c)


def stream_of(qids, arrival=0.0):
    return [runtime.StreamQuery(Q.build_query(q), arrival) for q in qids]


def small_catalog():
    return tpch.build_catalog(sf=0.05, num_nodes=1, rows_per_partition=500)


# ---------------------------------------------------------------- the codec
def test_frame_roundtrip_over_socketpair():
    a, b = socket.socketpair()
    try:
        hdr = {"kind": "exec", "req": 7, "parts": [["lineitem", 0]]}
        body = bytes(range(256)) * 3
        sent = W._write_frame(a, hdr, body)
        got_hdr, got_body, total = W._read_frame(b)
        assert got_hdr == hdr
        assert bytes(got_body) == body
        assert total == sent          # wire-byte accounting is symmetric
    finally:
        a.close()
        b.close()


def test_value_codec_roundtrip_and_writability():
    """Everything a push-plan result/aux can hold survives the tagged
    codec — nested containers, mixed dtypes, empty arrays/tables — and
    decoded arrays are writable (the replay mutates them in place)."""
    rng = np.random.default_rng(0)
    tab = ColumnTable({"a": rng.integers(0, 9, 50).astype(np.int32),
                       "b": rng.normal(size=50),
                       "c": rng.integers(0, 2, 50).astype(bool)})
    val = {"tables": [tab, ColumnTable({"x": np.array([], np.float64)})],
           "aux": ({"bitmap": np.packbits(np.ones(17, np.uint8)),
                    "rows": 17, "sel": 0.25, "tag": "q1", "none": None},
                   [np.arange(6, dtype=np.int64).reshape(2, 3), True]),
           3: "int-keyed"}
    bufs = []
    spec = W._enc(val, bufs)
    # header side is pure JSON-able structure; bytes ride separately
    import json
    json.dumps(spec)
    # the channel always decodes out of the received bytearray — that is
    # what makes frombuffer views writable downstream
    out = W._dec(spec, W._Cursor(bytearray(b"".join(bufs))))
    t0, t1 = out["tables"]
    assert_tables_identical(tab, t0)
    assert t1.columns == ["x"] and len(t1.cols["x"]) == 0
    aux, lst = out["aux"]
    assert isinstance(out["aux"], tuple) and isinstance(lst, list)
    np.testing.assert_array_equal(aux["bitmap"],
                                  np.packbits(np.ones(17, np.uint8)))
    assert aux["rows"] == 17 and aux["sel"] == 0.25
    assert aux["none"] is None and out[3] == "int-keyed"
    np.testing.assert_array_equal(lst[0], np.arange(6).reshape(2, 3))
    t0.cols["a"][0] = 99                # writable: no read-only frombuffer
    assert t0.cols["a"][0] == 99


def test_plan_codec_survives_derive_lambdas():
    """Real query plans carry lambdas in their ``derive`` tuples — the
    marshal-backed pickler must round-trip them to a plan that executes
    byte-identically; module-level functions still pickle by reference."""
    q = Q.build_query("Q1")
    plan = q.plans["lineitem"]
    assert plan.derive                  # the plan actually carries lambdas
    spec = W.encode_plan(plan)
    back = W.decode_plan(spec)
    data = CAT.tables["lineitem"][0].data
    ref, _ = execute_push_plan(plan, data)
    got, _ = execute_push_plan(back, data)
    assert_tables_identical(ref, got, "Q1 derive")
    # stable bytes: the same plan encodes to the same spec (the pool's
    # blake2b plan_key relies on it to dedupe shipping)
    assert W.encode_plan(plan) == spec


# ---------------------------------------------------- the tier oracle (PR-4)
def test_all_queries_byte_identical_random_decision_vectors(pool):
    """The acceptance bar: all 15 TPC-H queries, random pushdown/pushback
    decision vectors, process tier vs in-process oracle — merged tables
    byte-identical."""
    rng = np.random.default_rng(7)
    for qid in Q.QUERY_IDS:
        q = Q.build_query(qid)
        reqs = engine.plan_requests(q, CAT)
        dec = {r.req_id: (PUSHDOWN if rng.random() < 0.5 else PUSHBACK)
               for r in reqs}
        ref = runtime.execute_split(reqs, dec)
        got = runtime.execute_split(reqs, dec, retry=FAST, tier=pool)
        assert set(ref.merged) == set(got.merged), qid
        for table in ref.merged:
            assert_tables_identical(ref.merged[table], got.merged[table],
                                    (qid, table))
        assert (ref.n_pushdown, ref.n_pushback) == \
            (got.n_pushdown, got.n_pushback), qid
        assert got.n_demoted == 0       # healthy workers: no recovery


def test_engine_modes_byte_identical_across_tiers(pool):
    """run_query through the full engine (arbitration included) returns
    the same result table on both tiers, for every mode."""
    for qid in ("Q1", "Q6", "Q12"):
        for mode in (engine.MODE_ADAPTIVE, engine.MODE_EAGER):
            base = engine.EngineConfig(mode=mode, measured_feedback=False)
            proc = engine.EngineConfig(mode=mode, measured_feedback=False,
                                       worker_pool=pool, retry=FAST)
            ref = engine.run_query(Q.build_query(qid), CAT, base)
            got = engine.run_query(Q.build_query(qid), CAT, proc)
            assert_tables_identical(ref.result, got.result, (qid, mode))


def test_wire_bytes_flow_and_counters(pool, fresh_metrics):
    """Pushdown results and pushback projections cross the wire as real
    serialized bytes, counted by the wire.* counters."""
    q = Q.build_query("Q6")
    reqs = engine.plan_requests(q, CAT)
    half = {r.req_id: (PUSHDOWN if i % 2 == 0 else PUSHBACK)
            for i, r in enumerate(reqs)}
    before = pool.wire_bytes()
    runtime.execute_split(reqs, half, retry=FAST, tier=pool)
    after = pool.wire_bytes()
    assert after["sent"] > before["sent"]
    assert after["recv"] > before["recv"]
    c = fresh_metrics.snapshot()["counters"]
    assert c.get("wire.pushdown_result_bytes", 0) > 0
    assert c.get("wire.pushback_ship_bytes", 0) > 0


def test_storage_tier_config_resolution():
    assert engine.resolve_tier(engine.EngineConfig(), CAT) is None
    assert engine.resolve_tier(
        engine.EngineConfig(storage_tier=None), CAT) is None
    sentinel = object()
    assert engine.resolve_tier(
        engine.EngineConfig(worker_pool=sentinel), CAT) is sentinel
    with pytest.raises(ValueError):
        engine.resolve_tier(engine.EngineConfig(storage_tier="bogus"), CAT)


def test_pool_for_registry_reuses_and_closes():
    cat = small_catalog()
    p1 = W.pool_for(cat, pd_slots=1)
    try:
        assert W.pool_for(cat) is p1      # one pool per catalog
    finally:
        W.close_all_pools()
    assert p1.closed
    p2 = W.pool_for(cat, pd_slots=1)      # a closed pool is replaced
    try:
        assert p2 is not p1 and not p2.closed
    finally:
        W.close_all_pools()


# ----------------------------------------------------------- load signals
def test_load_signals_published_and_burn_pressure(pool, fresh_metrics):
    """Every worker publishes queue-depth/in-flight/CPU; ``burn`` raises
    real storage-side pressure that shows up in the very gauges
    MeasuredLoad reads."""
    loads = pool.publish_load()
    assert set(loads) == {0, 1}
    for node, snap in loads.items():
        assert {"exec_q", "ship_q", "inflight", "done"} <= set(snap)
    g = fresh_metrics.snapshot()["gauges"]
    for node in (0, 1):
        assert f"stream.node{node}.exec_queue" in g
        assert f"stream.node{node}.ship_queue" in g
        assert f"storage.node{node}.inflight" in g
    done0 = loads[0]["done"]
    pool.burn(0, 0.05, tasks=6)           # 6 x 50ms on 2 slots
    busy = pool.publish_load()[0]
    # pressure is visible while the burn is in flight: queued + running
    assert busy["exec_q"] + busy["inflight"] > 0
    g = fresh_metrics.snapshot()["gauges"]
    assert g["stream.node0.exec_queue"] == busy["exec_q"]
    deadline = time.monotonic() + 10.0
    max_cpu = busy.get("cpu") or 0.0
    while time.monotonic() < deadline:
        snap = pool.publish_load()[0]
        max_cpu = max(max_cpu, snap.get("cpu") or 0.0)
        if snap["done"] >= done0 + 6:
            break
        time.sleep(0.02)
    assert snap["done"] >= done0 + 6
    # the burn was real CPU: occupancy peaked strictly positive while the
    # worker was grinding (each poll samples the window since the last)
    assert max_cpu > 0


# --------------------------------------------- real faults -> PR-8 recovery
def test_dead_channel_raises_workerfault_and_records():
    p = W.WorkerPool(CAT, pd_slots=1)
    try:
        p.kill(1)
        reqs = engine.plan_requests(Q.build_query("Q6"), CAT)
        sub = [r for r in reqs if r.part.node_id == 1]
        cplan = compile_push_plan(sub[0].plan)
        deadline = time.monotonic() + 5.0
        while p.alive(1) and time.monotonic() < deadline:
            time.sleep(0.01)
        with pytest.raises(WorkerFault) as ei:
            p.execute_group(cplan, sub, EXECUTOR_BATCHED, None)
        assert ei.value.kind == "crash" and ei.value.node == 1
        assert p.fault_counts() == {"crash": 1}
        assert p.alive(0)                 # the blast radius is one node
    finally:
        p.close()


def test_overdue_request_raises_workerfault_timeout():
    cat = small_catalog()
    p = W.WorkerPool(cat, pd_slots=1, request_timeout_s=0.05)
    try:
        p.burn(0, 0.6, tasks=2)           # occupy the only slot + queue
        reqs = engine.plan_requests(Q.build_query("Q6"), cat)
        cplan = compile_push_plan(reqs[0].plan)
        with pytest.raises(WorkerFault) as ei:
            p.execute_group(cplan, reqs[:1], EXECUTOR_BATCHED, None)
        assert ei.value.kind == "timeout"
        assert p.fault_counts() == {"timeout": 1}
        assert p.alive(0)                 # overdue, not dead
    finally:
        p.close()


def test_stream_worker_kill_mid_wave_recovers_and_reconciles():
    """Satellite 4: SIGKILL a storage worker mid-wave (the worker's own
    pinned die_after schedule — deterministic by work-item count) and the
    stream must recover via retry -> demote-to-pushback with results
    byte-identical to the clean in-process run, and the pool's real-fault
    ledger reconciling exactly with the faults.* counters."""
    qids = ["Q1", "Q6", "Q12"]
    clean = runtime.run_stream(stream_of(qids), CAT,
                               engine.EngineConfig(measured_feedback=False),
                               time_scale=0)
    om.set_metrics(om.Metrics())          # isolate the chaotic run's ledger
    p = W.WorkerPool(CAT, pd_slots=2)
    try:
        p.die_after(0, 2)                 # node 0 dies at its 3rd work item
        cfg = engine.EngineConfig(worker_pool=p, retry=FAST,
                                  measured_feedback=False)
        run = runtime.run_stream(stream_of(qids), CAT, cfg, time_scale=0)
        for qid in qids:
            assert_tables_identical(clean.results[qid], run.results[qid],
                                    qid)
        assert not p.alive(0) and p.alive(1)
        assert run.n_demoted > 0          # recovery actually happened
        c = om.get_metrics().snapshot()["counters"]
        events = p.events
        assert len(events) > 0 and all(ev["node"] == 0 for ev in events)
        # exact reconciliation: every channel fault the pool recorded was
        # counted once by the recovery loop, by kind and by (node, path)
        assert c.get("faults.crash", 0) + c.get("faults.timeout", 0) == \
            len(events)
        per_node_path = sum(v for k, v in c.items()
                            if k.startswith("faults.node")
                            and k.endswith(".failures"))
        assert per_node_path == len(events)
        assert c.get("retry.demotions", 0) + \
            c.get("retry.local_replays", 0) > 0
        assert run.retries == c.get("retry.attempts", 0)
    finally:
        p.close()


def test_stream_worker_kill_no_demote_aggregates_error():
    """With ``demote_on_exhaust=False`` (the fail-to-error baseline) a
    killed worker surfaces as the aggregated RuntimeError whose cause is
    the FaultExhausted — not a hang, not a silent wrong answer."""
    p = W.WorkerPool(CAT, pd_slots=2)
    try:
        p.die_after(0, 0)                 # first work item kills node 0
        cfg = engine.EngineConfig(
            worker_pool=p,
            retry=RetryPolicy(sleep_scale=0.0, demote_on_exhaust=False),
            measured_feedback=False)
        with pytest.raises(RuntimeError) as ei:
            runtime.run_stream(stream_of(["Q6"]), CAT, cfg, time_scale=0)
        assert isinstance(ei.value.__cause__, FaultExhausted)
        assert ei.value.__cause__.kind == "crash"
    finally:
        p.close()


def test_split_recovery_after_kill_is_byte_identical():
    """execute_split (no stream) against a freshly killed worker: every
    node-0 group demotes, results stay byte-identical, outcomes carry the
    recovery accounting."""
    p = W.WorkerPool(CAT, pd_slots=1)
    try:
        p.kill(0)
        deadline = time.monotonic() + 5.0
        while p.alive(0) and time.monotonic() < deadline:
            time.sleep(0.01)
        q = Q.build_query("Q14")
        reqs = engine.plan_requests(q, CAT)
        dec = {r.req_id: PUSHDOWN for r in reqs}
        ref = runtime.execute_split(reqs, dec)
        got = runtime.execute_split(reqs, dec, retry=FAST, tier=p)
        for table in ref.merged:
            assert_tables_identical(ref.merged[table], got.merged[table],
                                    table)
        assert got.n_demoted == sum(1 for r in reqs
                                    if r.part.node_id == 0)
        demoted = {o.req_id for o in got.outcomes if o.demoted}
        assert demoted == {r.req_id for r in reqs if r.part.node_id == 0}
    finally:
        p.close()


# ------------------------------------------------------ staleness + tracing
def test_catalog_mutation_triggers_reship():
    """append_to_partition bumps the version stamp; the pool re-ships the
    stale partition so the worker never serves old bytes."""
    cat = small_catalog()
    p = W.WorkerPool(cat, pd_slots=1)
    try:
        q = Q.build_query("Q6")
        reqs = engine.plan_requests(q, cat)
        dec = {r.req_id: PUSHDOWN for r in reqs}
        before = runtime.execute_split(reqs, dec, retry=FAST, tier=p)
        part = cat.tables["lineitem"][0]
        extra = ColumnTable({c: np.asarray(v)[:64]
                             for c, v in part.data.cols.items()})
        cat.append_to_partition("lineitem", 0, extra)
        reqs2 = engine.plan_requests(q, cat)
        dec2 = {r.req_id: PUSHDOWN for r in reqs2}
        ref = runtime.execute_split(reqs2, dec2)
        got = runtime.execute_split(reqs2, dec2, retry=FAST, tier=p)
        assert_tables_identical(ref.merged["lineitem"],
                                got.merged["lineitem"], "post-append")
        # the result really moved: stale bytes would have reproduced
        # `before` instead
        b, g = before.merged["lineitem"], got.merged["lineitem"]
        assert any(not np.array_equal(b.cols[c], g.cols[c])
                   for c in b.columns)
    finally:
        p.close()


def test_worker_spans_stitched_into_compute_trace(pool):
    """Span-id handoff: worker-side spans come back in the response and
    are adopted under the dispatching compute-side span, echoing it as
    ``remote_parent`` and carrying the worker's pid."""
    q = Q.build_query("Q6")
    reqs = engine.plan_requests(q, CAT)
    dec = {r.req_id: (PUSHDOWN if i % 2 == 0 else PUSHBACK)
           for i, r in enumerate(reqs)}
    with T.tracing() as tr:
        runtime.execute_split(reqs, dec, retry=FAST, tier=pool)
    execs = tr.find("worker_execute")
    fetches = tr.find("worker_fetch")
    assert execs and fetches
    sids = {s.sid: s for s in tr.snapshot()}
    for sp in execs + fetches:
        assert sp.cat == "worker"
        assert sp.attrs["pid"] != os.getpid()     # really remote
        assert sp.dur is not None and sp.dur >= 0
        assert sp.parent is not None
        assert sp.attrs["remote_parent"] == sp.parent
        parent = sids[sp.parent]
        assert parent.name in ("storage_execute", "compute_replay")
    nodes = {sp.attrs["node"] for sp in execs}
    assert nodes <= {0, 1}
