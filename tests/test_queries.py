"""End-to-end query correctness: every TPC-H query returns IDENTICAL
results in all four execution modes, and matches a golden oracle that
executes the same logical query on the unpartitioned tables (no pushdown
machinery at all)."""
import numpy as np
import pytest

from repro.core import engine
from repro.core.plan import execute_push_plan
from repro.queryproc import queries as Q
from repro.queryproc import tpch
from repro.queryproc.table import ColumnTable

CAT = tpch.build_catalog(sf=1.0, num_nodes=2, rows_per_partition=4_000)


def _golden(query):
    """Run the pushable plans on whole unpartitioned tables + compute()."""
    merged = {}
    for table, plan in query.plans.items():
        full = CAT.scan_table(table)
        res, _ = execute_push_plan(plan, full)
        merged[table] = res
    return query.compute(merged)


@pytest.mark.parametrize("qid", Q.QUERY_IDS)
def test_modes_agree_and_match_golden(qid):
    q = Q.build_query(qid)
    golden = _golden(q)
    for mode in engine.MODES:
        r = engine.run_query(q, CAT, engine.EngineConfig(mode=mode))
        assert engine.results_equal(r.result, golden), \
            f"{qid} mode={mode} diverges from golden"
        assert len(r.requests) > 0
        assert r.sim.admitted(qid) + r.sim.pushed_back_by_query.get(qid, 0) \
            == len(r.requests)


@pytest.mark.parametrize("qid", ["Q14", "Q19"])
@pytest.mark.parametrize("sel", [0.1, 0.5, 0.9])
def test_selectivity_knob(qid, sel):
    q = Q.build_query(qid, fact_selectivity=sel)
    li = CAT.scan_table("lineitem")
    from repro.queryproc import expressions as ex
    frac = ex.evaluate(q.plans["lineitem"].predicate, li).mean()
    assert abs(frac - sel) < 0.06  # l_quantity uniform 1..50
    golden = _golden(q)
    r = engine.run_query(q, CAT, engine.EngineConfig(mode="adaptive"))
    assert engine.results_equal(r.result, golden)


def test_concurrent_matches_solo():
    qs = [Q.build_query("Q12"), Q.build_query("Q14")]
    runs = engine.run_concurrent(qs, CAT, engine.EngineConfig(mode="adaptive_pa"))
    for q in qs:
        golden = _golden(q)
        assert engine.results_equal(runs[q.qid].result, golden)


def test_partition_counts():
    li_parts = CAT.partitions_of("lineitem")
    assert len(li_parts) > 4
    total = sum(len(p.data) for p in li_parts)
    assert total == len(CAT.scan_table("lineitem"))
    # partitions spread over both nodes
    assert {p.node_id for p in li_parts} == {0, 1}


def test_q1_partial_agg_reassembles():
    """Partial grouped agg per partition + merge == full-table agg."""
    q = Q.build_query("Q1")
    golden = _golden(q)
    r = engine.run_query(q, CAT, engine.EngineConfig(mode="eager"))
    assert engine.results_equal(r.result, golden)
    assert len(r.result) <= 6  # 3 returnflags x 2 linestatus
    cnt = r.result.cols["cnt"].sum()
    li = CAT.scan_table("lineitem")
    want = (li.cols["l_shipdate"] <= tpch.date(1998, 8, 2) - 90).sum()
    assert cnt == want
