"""Compiler subsystem: amenability classification per IR node type,
splitter golden tests (storage frontier + residual shape per TPC-H query),
and end-to-end equivalence of every compiled query against the seed's
hand-built plans — including queries where the compiler pushes a strictly
larger frontier."""
import numpy as np
import pytest

from repro.compiler import (analyzer, compile_query, compile_query_detailed,
                            interpreter, ir, splitter)
from repro.compiler.splitter import frontier_signature, frontier_size
from repro.core import engine
from repro.queryproc import queries as Q
from repro.queryproc import tpch
from repro.queryproc.expressions import Col
from repro.queryproc.table import ColumnTable

CAT = tpch.build_catalog(sf=1.0, num_nodes=2, rows_per_partition=4_000)
CFG = engine.EngineConfig(mode="eager")


# --------------------------------------------------- amenability analysis
_SCAN = ir.Scan("lineitem", ("l_orderkey",))


@pytest.mark.parametrize("node,pushable,partial", [
    (_SCAN, True, False),
    (ir.Filter(_SCAN, Col("l_quantity") < 10), True, False),
    (ir.Project(_SCAN, ("l_orderkey",)), True, False),
    (ir.Map(_SCAN, (("x", ("l_quantity",), lambda q: q * 2),)), True, False),
    (ir.Aggregate(_SCAN, ("l_orderkey",), (("s", "sum", "l_quantity"),)),
     True, True),
    (ir.Aggregate(_SCAN, ("l_orderkey",), (("m", "mean", "l_quantity"),)),
     False, False),  # mean does not decompose into partials
    (ir.TopK(_SCAN, "l_quantity", 5), True, True),
    (ir.Shuffle(_SCAN, "l_orderkey"), True, False),
    (ir.Join(_SCAN, ir.Scan("orders", ("o_orderkey",)),
             "l_orderkey", "o_orderkey"), False, False),
    (ir.SemiJoin(_SCAN, ir.Scan("orders", ("o_orderkey",)),
                 "l_orderkey", "o_orderkey"), False, False),
    (ir.Sort(_SCAN, ("l_orderkey",)), False, False),
    (ir.PyOp((_SCAN,), lambda t: t), False, False),
])
def test_amenability_per_node_type(node, pushable, partial):
    am = analyzer.classify(node)
    assert am.pushable == pushable and am.partial == partial
    assert am.reason  # every verdict carries its §4.1 justification


def test_analyzer_report_counts():
    rep = analyzer.report(compile_query_detailed("Q3").root)
    assert rep["Join"]["blocked"] == 2
    assert rep["Filter"]["pushable"] == 3
    assert rep["TopK"]["partial"] == 1


# ------------------------------------------------- splitter golden tests
# per-query pushed stages per table + residual operator counts (shape)
GOLDEN_FRONTIER = {
    "Q1": {"lineitem": "scan+filter+derive+agg"},
    "Q3": {"customer": "scan+filter", "lineitem": "scan+filter+derive",
           "orders": "scan+filter"},
    "Q4": {"lineitem": "scan+derive", "orders": "scan+filter"},
    "Q5": {"customer": "scan", "lineitem": "scan+derive",
           "nation": "scan+filter", "orders": "scan+filter",
           "supplier": "scan"},
    "Q6": {"lineitem": "scan+filter+derive+agg"},
    "Q7": {"customer": "scan", "lineitem": "scan+filter+derive",
           "orders": "scan", "supplier": "scan"},
    "Q8": {"customer": "scan", "lineitem": "scan+derive",
           "nation": "scan+filter", "orders": "scan+filter",
           "part": "scan+filter", "supplier": "scan"},
    "Q10": {"customer": "scan", "lineitem": "scan+filter+derive",
            "orders": "scan+filter"},
    "Q12": {"lineitem": "scan+filter+derive", "orders": "scan"},
    "Q14": {"lineitem": "scan+filter+derive", "part": "scan"},
    "Q15": {"lineitem": "scan+filter+derive+agg", "supplier": "scan"},
    "Q17": {"lineitem": "scan", "part": "scan+filter"},
    "Q18": {"lineitem": "scan+agg", "orders": "scan"},
    "Q19": {"lineitem": "scan+filter+derive", "part": "scan"},
    "Q22": {"customer": "scan+filter", "orders": "scan"},
}

GOLDEN_RESIDUAL = {  # node-type multiset of the residual plan
    "Q1": {"Merged": 1, "Aggregate": 1, "Sort": 1},
    "Q3": {"Merged": 3, "Join": 2, "Aggregate": 1, "TopK": 1},
    "Q4": {"Merged": 2, "Filter": 1, "SemiJoin": 1, "Aggregate": 1},
    "Q5": {"Merged": 5, "Join": 4, "Filter": 1, "Aggregate": 1, "Sort": 1},
    "Q6": {"Merged": 1, "Aggregate": 1},
    "Q7": {"Merged": 4, "Join": 3, "Filter": 1, "Map": 1, "Aggregate": 1,
           "Sort": 1},
    "Q8": {"Merged": 6, "Join": 5, "Map": 2, "Aggregate": 1, "Project": 1},
    "Q10": {"Merged": 3, "Join": 2, "Aggregate": 1, "TopK": 1},
    "Q12": {"Merged": 2, "Filter": 1, "Join": 1, "Map": 1, "Aggregate": 1,
            "Sort": 1},
    "Q14": {"Merged": 2, "Join": 1, "Map": 2, "Aggregate": 1, "Project": 1},
    "Q15": {"Merged": 2, "Aggregate": 1, "PyOp": 1, "Join": 1},
    "Q17": {"Merged": 2, "Join": 2, "Aggregate": 2, "Map": 2, "Filter": 1,
            "Project": 1},
    "Q18": {"Merged": 2, "Aggregate": 1, "Filter": 1, "Join": 1, "TopK": 1},
    "Q19": {"Merged": 2, "Join": 1, "Filter": 1, "Aggregate": 1},
    "Q22": {"Merged": 2, "Filter": 0, "PyOp": 1, "SemiJoin": 1,
            "Aggregate": 1, "Sort": 1},
}


@pytest.mark.parametrize("qid", Q.QUERY_IDS)
def test_splitter_golden(qid):
    cq = compile_query_detailed(qid)
    assert frontier_signature(cq.query.plans) == GOLDEN_FRONTIER[qid]
    counts = {k: v for k, v in ir.op_counts(cq.residual).items() if v}
    want = {k: v for k, v in GOLDEN_RESIDUAL[qid].items() if v}
    assert counts == want, f"{qid} residual shape changed: {counts}"


@pytest.mark.parametrize("qid", Q.QUERY_IDS)
def test_shuffle_keys_match_seed(qid):
    assert (compile_query(qid).shuffle_keys
            == Q.build_query_legacy(qid).shuffle_keys)


# ------------------------------------------- end-to-end equivalence (seed)
@pytest.mark.parametrize("qid", Q.QUERY_IDS)
def test_compiled_equals_hand_built(qid):
    """compile_query -> split -> engine == the seed's hand-built plans."""
    rc = engine.run_query(compile_query(qid), CAT, CFG)
    rl = engine.run_query(Q.build_query_legacy(qid), CAT, CFG)
    assert engine.results_equal(rc.result, rl.result), qid
    assert len(rc.requests) > 0


@pytest.mark.parametrize("qid", ["Q14", "Q18", "Q19"])
@pytest.mark.parametrize("sel", [0.1, 0.5, 0.9])
def test_compiled_equals_hand_built_selectivity(qid, sel):
    # Q18 guards the substitution rewrite: its HAVING-style residual
    # filter (sum_qty > t, an Aggregate output) must survive
    rc = engine.run_query(compile_query(qid, fact_selectivity=sel), CAT, CFG)
    rl = engine.run_query(Q.build_query_legacy(qid, fact_selectivity=sel),
                          CAT, CFG)
    assert engine.results_equal(rc.result, rl.result), (qid, sel)


@pytest.mark.parametrize("qid", ["Q5", "Q8"])
def test_compiler_pushes_strictly_larger_frontier(qid):
    """The compiler pushes dimension filters (Q5/Q8 region restrictions)
    the hand-built plans evaluated at compute: same result, strictly more
    pushed stages, strictly fewer bytes shipped for that table."""
    cq = compile_query_detailed(qid)
    legacy = Q.build_query_legacy(qid)
    assert frontier_size(cq.query.plans) > frontier_size(legacy.plans)
    assert cq.query.plans["nation"].predicate is not None
    assert legacy.plans["nation"].predicate is None
    rc = engine.run_query(cq.query, CAT, CFG)
    rl = engine.run_query(legacy, CAT, CFG)
    assert engine.results_equal(rc.result, rl.result)


def test_q22_pushes_stronger_predicate():
    """Q22: the nation-list conjunct joins c_acctbal>0 at storage."""
    from repro.queryproc import expressions as ex
    comp = compile_query("Q22").plans["customer"].predicate
    legacy = Q.build_query_legacy("Q22").plans["customer"].predicate
    assert ex.columns_of(comp) == {"c_acctbal", "c_nationkey"}
    assert ex.columns_of(legacy) == {"c_acctbal"}


# ------------------------------------------------- interpreter/unit level
def test_interpreter_shared_subtree_evaluated_once():
    calls = []

    def probe(t):
        calls.append(1)
        return t

    base = ir.Merged("t")
    shared = ir.PyOp((base,), probe)
    root = ir.Join(shared, shared, "k", "k")
    t = ColumnTable({"k": np.asarray([1, 2, 3])})
    interpreter.run(root, {"t": t})
    assert len(calls) == 1


def test_interpreter_memoizes_shared_aggregate_subtree():
    """The memo is per-run and id-keyed: a diamond over the same Aggregate
    object must evaluate it once, and both consumers must see the *same*
    table object (not an equal copy)."""
    seen = []
    agg = ir.Aggregate(ir.Merged("t"), ("k",), (("s", "sum", "v"),))
    tap = ir.PyOp((agg,), lambda t: (seen.append(t), t)[1])
    root = ir.Join(ir.PyOp((agg,), lambda t: (seen.append(t), t)[1]),
                   tap, "k", "k")
    t = ColumnTable({"k": np.asarray([1, 1, 2]),
                     "v": np.asarray([10.0, 20.0, 30.0])})
    out = interpreter.run(root, {"t": t})
    assert seen[0] is seen[1]   # one evaluation, one object
    assert len(out) == 2


def test_interpreter_project_drops_missing_columns():
    """ir.Project keeps only columns present in the input — a residual
    Project may name columns the pushed frontier already consumed."""
    t = ColumnTable({"a": np.arange(4), "b": np.arange(4) * 2})
    out = interpreter.run(ir.Project(ir.Merged("t"), ("b", "ghost")),
                          {"t": t})
    assert list(out.cols) == ["b"]
    assert np.array_equal(out.cols["b"], np.arange(4) * 2)


def test_interpreter_semijoin_duplicate_right_keys():
    """SemiJoin membership is set semantics regardless of right-side key
    duplication (the np.unique pre-pass was dropped as redundant)."""
    left = ColumnTable({"k": np.asarray([1, 2, 3, 4])})
    right = ColumnTable({"rk": np.asarray([2, 2, 4, 4, 4])})
    semi = interpreter.run(
        ir.SemiJoin(ir.Merged("l"), ir.Merged("r"), "k", "rk"),
        {"l": left, "r": right})
    assert np.array_equal(semi.cols["k"], [2, 4])
    anti = interpreter.run(
        ir.SemiJoin(ir.Merged("l"), ir.Merged("r"), "k", "rk", anti=True),
        {"l": left, "r": right})
    assert np.array_equal(anti.cols["k"], [1, 3])


def test_pred_cache_lru_eviction(monkeypatch):
    """_PRED_CACHE evicts least-recently-used at capacity instead of
    clearing wholesale; a touch refreshes an entry's recency."""
    monkeypatch.setattr(interpreter, "_PRED_CACHE_CAP", 4)
    interpreter._PRED_CACHE.clear()
    t = ColumnTable({"a": np.arange(8)})
    nodes = [ir.Filter(ir.Merged("t"), Col("a") < i) for i in range(6)]
    for n in nodes:   # list keeps the nodes alive -> ids stay unique
        interpreter.run(n, {"t": t})
    assert len(interpreter._PRED_CACHE) == 4
    assert set(interpreter._PRED_CACHE) == {id(n) for n in nodes[2:]}
    interpreter.run(nodes[2], {"t": t})   # refresh the oldest survivor
    extra = ir.Filter(ir.Merged("t"), Col("a") < 99)
    interpreter.run(extra, {"t": t})
    assert id(nodes[2]) in interpreter._PRED_CACHE   # refreshed: kept
    assert id(nodes[3]) not in interpreter._PRED_CACHE   # LRU: evicted
    interpreter._PRED_CACHE.clear()


def test_splitter_absorbs_topk_without_agg():
    """scan+filter+topk chain: partial top-k pushes, residual re-selects."""
    n = ir.TopK(ir.Filter(ir.Scan("lineitem", ("l_orderkey", "l_quantity")),
                          Col("l_quantity") < 30), "l_quantity", 7)
    sp = splitter.split(n)
    assert sp.plans["lineitem"].top_k == ("l_quantity", 7, False)
    assert isinstance(sp.residual, ir.TopK)  # merge obligation
    merged = {"lineitem": ColumnTable.concat(
        [engine.execute_push_plan(sp.plans["lineitem"], p.data)[0]
         for p in CAT.partitions_of("lineitem")][:4])}
    out = interpreter.run(sp.residual, merged)
    assert len(out) == 7


def test_splitter_rejects_topk_over_partial_agg():
    """top-k over partial aggregates could drop the true winner — the
    splitter must keep the TopK (and re-aggregation) at compute."""
    n = ir.Aggregate(ir.Scan("lineitem", ()), ("l_orderkey",),
                     (("s", "sum", "l_quantity"),))
    n = ir.TopK(n, "s", 3)
    sp = splitter.split(n)
    assert sp.plans["lineitem"].top_k is None
    assert isinstance(sp.residual, ir.TopK)
    assert isinstance(sp.residual.child, ir.Aggregate)


def test_splitter_keeps_derived_col_filter_residual():
    """A filter over a Map-derived column cannot precede the derive at
    storage (PushPlan stage order) — it must stay in the residual."""
    n = ir.Map(ir.Scan("lineitem", ("l_orderkey",)),
               (("flag", ("l_quantity",),
                 lambda q: (q > 10).astype(np.int32)),))
    n = ir.Filter(n, Col("flag").eq(1))
    sp = splitter.split(n)
    assert sp.plans["lineitem"].predicate is None
    assert isinstance(sp.residual, ir.Filter)


def test_splitter_respects_project_over_derive():
    """A Project that drops a Map-derived intermediate decides the pushed
    output schema — the splitter must not re-add the derived column."""
    n = ir.Map(ir.Scan("lineitem", ("l_orderkey",)),
               (("x", ("l_quantity",), lambda q: q * 2.0),))
    n = ir.Project(n, ("l_orderkey",))
    sp = splitter.split(n)
    assert sp.plans["lineitem"].columns == ("l_orderkey",)
    out, _ = engine.execute_push_plan(sp.plans["lineitem"],
                                      CAT.partitions_of("lineitem")[0].data)
    assert out.columns == ["l_orderkey"]


def test_substitution_keeps_filters_above_aggregate():
    """A base-column filter above an Aggregate is residual (the splitter
    never pushes it) — substitute_fact_predicate must not delete it."""
    from repro.compiler import substitute_fact_predicate
    n = ir.Aggregate(ir.Scan("lineitem", ()), ("l_orderkey",),
                     (("s", "sum", "l_quantity"),))
    n = ir.Filter(n, Col("l_orderkey") < 100)
    sub = substitute_fact_predicate(n, Col("l_quantity") <= 10)
    assert ir.describe(sub) == "Filter(Aggregate(Filter(Scan[lineitem])))"
    assert isinstance(sub, ir.Filter)  # the l_orderkey filter survives
    assert sub.predicate.col.name == "l_orderkey"


def test_splitter_absorbed_topk_ships_ordering_column():
    """TopK over a scan that didn't export the ordering column: the
    splitter must add it to the pushed schema so both the storage-side
    select and the residual re-select can execute."""
    from repro.compiler import compile_ir
    cq = compile_ir(ir.TopK(ir.Scan("lineitem", ("l_orderkey",)),
                            "l_quantity", 5), "T")
    assert "l_quantity" in cq.plans["lineitem"].columns
    r = engine.run_query(cq.query, CAT, CFG)
    assert len(r.result) == 5
    assert float(r.result.cols["l_quantity"].min()) == 50.0  # top qty


def test_estimate_cost_handles_derived_agg_key():
    """A pushed Aggregate keyed by a Map-derived column (legal compiler
    output) must not crash the cost model's NDV lookup."""
    from repro.compiler import compile_ir
    n = ir.Map(ir.Scan("lineitem", ()),
               (("l_year", ("l_shipdate",),
                 lambda s: (s // 365).astype(np.int32)),))
    n = ir.Aggregate(n, ("l_year",), (("s", "sum", "l_quantity"),))
    cq = compile_ir(n, "DK")
    r = engine.run_query(cq.query, CAT, CFG)  # plan_requests -> estimate_cost
    li = CAT.scan_table("lineitem")
    want = float(li.cols["l_quantity"].sum())
    assert abs(float(r.result.cols["s"].sum()) - want) < 1e-6 * want


def test_splitter_rejects_double_scan():
    two = ir.Join(ir.Scan("orders", ("o_orderkey",)),
                  ir.Scan("orders", ("o_custkey",)), "o_orderkey",
                  "o_custkey")
    with pytest.raises(splitter.CompileError):
        splitter.split(two)


@pytest.mark.parametrize("builder", [
    lambda: compile_query("Q14", fact_selectivity=0.0),
    lambda: Q.build_query_legacy("Q14", fact_selectivity=0.0)])
def test_zero_selectivity_keeps_schema(builder):
    """A fact predicate matching zero rows on every partition must still
    produce a joinable 0-row table (ColumnTable.concat keeps the schema)."""
    r = engine.run_query(builder(), CAT, CFG)
    assert len(r.result) == 1
    assert float(r.result.cols["promo_revenue"][0]) == 0.0


def test_engine_compile_and_run_entry_point():
    r = engine.compile_and_run("Q6", CAT, CFG)
    rl = engine.run_query(Q.build_query_legacy("Q6"), CAT, CFG)
    assert engine.results_equal(r.result, rl.result)


def test_shared_pushability_rule_matches_splitter():
    """The drift guard the unification exists for: on every TPC-H IR, each
    Filter that pushability.filter_absorbable accepts on a Scan chain must
    have its predicate absorbed by the splitter, and each one it rejects
    must survive in the residual — the absorption rule and the
    substitution walk are now literally the same function."""
    from repro.compiler import pushability, tpch_ir

    def chain_filters(root):
        for node in ir.walk(root):
            if (isinstance(node, ir.Filter)
                    and pushability.chain_scan_table(node) is not None):
                yield node

    for qid in Q.QUERY_IDS:
        root = tpch_ir.build_ir(qid)
        sp = splitter.split(root)
        residual_filters = [n for n in ir.walk(sp.residual)
                            if isinstance(n, ir.Filter)]
        residual_preds = [ir.describe(n) + repr(n.predicate)
                          for n in residual_filters]
        for f in chain_filters(root):
            table = pushability.chain_scan_table(f)
            if pushability.filter_absorbable(f):
                # absorbed: its columns feed the pushed predicate
                plan = sp.plans[table]
                assert plan.predicate is not None, (qid, table)
                from repro.queryproc import expressions as ex
                assert (ex.columns_of(f.predicate)
                        <= ex.columns_of(plan.predicate)), (qid, table)
            else:
                # rejected: an identical Filter must appear in the residual
                assert any(repr(f.predicate) in p for p in residual_preds), \
                    (qid, table, f.predicate)


# ------------------------------------------------ batchable frontier marks
def test_split_marks_frontiers_batchable():
    """Every split marks its frontiers with the stages the batch executor
    fuses — shuffle-bearing branches carry the 'shuffle' stage (the §4.2
    partition function runs inside the same fused pass, PR 3)."""
    for qid in Q.QUERY_IDS:
        cq = compile_query_detailed(qid)
        assert set(cq.batchable) == set(cq.plans), qid
        for table, stages in cq.batchable.items():
            plan = cq.plans[table]
            assert ("filter" in stages) == (plan.predicate is not None), \
                (qid, table)
            assert ("agg" in stages) == (plan.agg is not None), (qid, table)
            assert ("shuffle" in stages) == (
                table in cq.query.shuffle_keys), (qid, table)
        # the shuffle-aware signature is a superset of the plain one
        plain = cq.frontier_signature()
        marked = cq.frontier_signature(with_shuffle=True)
        for table in plain:
            assert marked[table].startswith(plain[table]), (qid, table)
            if table in cq.query.shuffle_keys:
                assert marked[table].endswith("+shuffle"), (qid, table)
