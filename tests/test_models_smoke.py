"""Per-architecture smoke tests on REDUCED configs (CPU, 1 device).

Each arch: one forward + one train-step gradient, asserting output shapes
and finite values; plus a prefill/decode consistency check (decode logits at
position S must match teacher-forced forward logits)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import api

jax.config.update("jax_platform_name", "cpu")


def make_batch(cfg, B=2, S=64, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_audio_frames, cfg.d_model)), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_patches, cfg.patch_dim)), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, reduced=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 64
    batch = make_batch(cfg, B, S)
    logits, aux, mask, _ = api.forward(params, cfg, batch)
    S_out = S + (cfg.num_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (B, S_out, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert jnp.isfinite(jnp.asarray(aux)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_grad_finite(arch):
    cfg = get_config(arch, reduced=True)
    params = api.init_params(cfg, jax.random.PRNGKey(1))
    batch = make_batch(cfg, 2, 64)
    loss, grads = jax.value_and_grad(
        lambda p: api.loss_fn(p, cfg, batch, remat=True))(params)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g.astype(jnp.float32)).all()) for g in flat)
    # loss should be near ln(vocab) for random init
    assert 0.5 * np.log(cfg.vocab_size) < float(loss) < 3.0 * np.log(cfg.vocab_size)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    """decode_step at position S must reproduce forward logits[:, S]."""
    cfg = get_config(arch, reduced=True)
    params = api.init_params(cfg, jax.random.PRNGKey(2))
    B, S = 2, 32
    batch = make_batch(cfg, B, S + 1)
    full_logits, _, _, _ = api.forward(params, cfg, batch)

    prefix = dict(batch)
    prefix["tokens"] = batch["tokens"][:, :S]
    pos = S + (cfg.num_patches if cfg.family == "vlm" else 0)
    last_logits, cache = api.build_decode_cache(params, cfg, prefix, pos + 8,
                                                blockwise=False)
    np.testing.assert_allclose(
        np.asarray(last_logits, np.float32),
        np.asarray(full_logits[:, -2], np.float32), rtol=2e-2, atol=2e-2)

    logits_dec, _ = api.decode_step(params, cfg, cache, jnp.int32(pos),
                                    batch["tokens"][:, S:S + 1])
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0], np.float32),
        np.asarray(full_logits[:, -1], np.float32), rtol=2e-2, atol=2e-2)
