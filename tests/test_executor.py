"""Fused batched executor == per-partition reference, byte for byte.

The batched executor (core.executor) must be indistinguishable from the
interpretive per-partition path (core.plan.execute_push_plan): identical
merged tables (same columns, dtypes, values, row order) for every TPC-H
query plan, identical end-to-end results in all four engine modes, and
identical cost estimates. Property tests cover segment-keyed partial
aggregation over adversarial partitionings (hypothesis optional: a
deterministic sweep covers the same invariants when absent)."""
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:  # optional dependency — see pyproject.toml [test]
    HAVE_HYPOTHESIS = False

from repro.core import engine
from repro.core.executor import CompiledPushPlan, compile_push_plan
from repro.core.plan import PushPlan, estimate_cost, execute_push_plan
from repro.queryproc import queries as Q
from repro.queryproc import tpch
from repro.queryproc.expressions import Col
from repro.queryproc.table import ColumnTable

CAT = tpch.build_catalog(sf=1.0, num_nodes=2, rows_per_partition=4_000)


def assert_tables_identical(a: ColumnTable, b: ColumnTable, ctx=""):
    assert a.columns == b.columns, (ctx, a.columns, b.columns)
    for c in a.columns:
        x, y = a.cols[c], b.cols[c]
        assert x.dtype == y.dtype, (ctx, c, x.dtype, y.dtype)
        assert np.array_equal(x, y, equal_nan=True), (ctx, c)


def _check_batch_equals_reference(plan: PushPlan, parts):
    ref = ColumnTable.concat([execute_push_plan(plan, p)[0] for p in parts])
    bat = compile_push_plan(plan).execute_batch(parts)
    assert_tables_identical(ref, bat, plan.table)


# ------------------------------------------------- all queries, all modes
@pytest.mark.parametrize("qid", Q.QUERY_IDS)
def test_merged_tables_byte_identical(qid):
    """Per-(table, plan) merged pushdown results are byte-identical."""
    q = Q.build_query(qid)
    for table, plan in q.plans.items():
        parts = [p.data for p in CAT.partitions_of(table)]
        _check_batch_equals_reference(plan, parts)


@pytest.mark.parametrize("threshold", [0.0, 1.5])
@pytest.mark.parametrize("qid", Q.QUERY_IDS)
def test_adaptive_filter_branches_byte_identical(qid, threshold):
    """Both adaptive filter-stage branches (forced concat-everything at
    threshold 0, forced gather-survivors at 1.5) produce the same bytes as
    the reference — the branch choice is purely a performance decision."""
    q = Q.build_query(qid)
    for table, plan in q.plans.items():
        parts = [p.data for p in CAT.partitions_of(table)]
        ref = ColumnTable.concat(
            [execute_push_plan(plan, p)[0] for p in parts])
        bat = compile_push_plan(plan).execute_batch(parts,
                                                    threshold=threshold)
        assert_tables_identical(ref, bat, (qid, table, threshold))


@pytest.mark.parametrize("qid", Q.QUERY_IDS)
def test_batch_parts_byte_identical(qid):
    """execute_batch_parts splits the fused pass back into per-partition
    tables identical to each per-partition reference result."""
    q = Q.build_query(qid)
    for table, plan in q.plans.items():
        parts = [p.data for p in CAT.partitions_of(table)]
        got, aux = compile_push_plan(plan).execute_batch_parts(parts)
        for p, g, a in zip(parts, got, aux):
            ref, ref_aux = execute_push_plan(plan, p)
            assert_tables_identical(ref, g, (qid, table))
            assert ref_aux == a == {}


# ------------------------------------------- aux outputs: bitmap, shuffle
@pytest.mark.parametrize("qid", Q.QUERY_IDS)
def test_bitmap_only_batch_byte_identical(qid):
    """The §4.2 bitmap-emission path: every predicate-bearing plan's
    bitmap_only variant produces per-partition packed bitmaps and filtered
    tables identical to the per-partition reference."""
    import dataclasses
    q = Q.build_query(qid)
    checked = 0
    for table, plan in q.plans.items():
        if plan.predicate is None or plan.apply_bitmap:
            continue
        bplan = dataclasses.replace(plan, bitmap_only=True)
        parts = [p.data for p in CAT.partitions_of(table)]
        got, aux = compile_push_plan(bplan).execute_batch_parts(parts)
        for p, g, a in zip(parts, got, aux):
            ref, ref_aux = execute_push_plan(bplan, p)
            assert_tables_identical(ref, g, (qid, table))
            np.testing.assert_array_equal(ref_aux["bitmap"], a["bitmap"])
        checked += 1
    if qid != "Q18":      # Q18's fact predicate lives above the pushed agg
        assert checked, f"{qid}: no predicate-bearing plan exercised"


@pytest.mark.parametrize("qid", Q.QUERY_IDS)
def test_shuffle_batch_byte_identical(qid):
    """The §4.2 shuffle path: per-partition hash-partition slices and
    position vectors from the batch pass match the reference exactly."""
    import dataclasses
    q = Q.build_query(qid)
    for table, plan in q.plans.items():
        # the shuffle key must be in the plan's output schema
        key = q.shuffle_keys.get(table)
        if key is None or key not in plan.columns:
            key = next((c for c in plan.columns if c in
                        CAT.partitions_of(table)[0].data.cols), None)
        if key is None:
            continue
        splan = dataclasses.replace(plan, shuffle=(key, 4))
        parts = [p.data for p in CAT.partitions_of(table)]
        got, aux = compile_push_plan(splan).execute_batch_parts(parts)
        for p, g, a in zip(parts, got, aux):
            ref, ref_aux = execute_push_plan(splan, p)
            assert_tables_identical(ref, g, (qid, table))
            np.testing.assert_array_equal(ref_aux["position_vector"],
                                          a["position_vector"])
            assert len(ref_aux["shuffle_parts"]) == len(a["shuffle_parts"])
            for rp, bp in zip(ref_aux["shuffle_parts"], a["shuffle_parts"]):
                assert_tables_identical(rp, bp, (qid, table, key))


def test_single_partition_execute_emits_aux():
    """CompiledPushPlan.execute now serves aux-producing plans too."""
    import dataclasses
    plan = Q.build_query("Q3").plans["lineitem"]  # filter+derive, no agg
    part = CAT.partitions_of("lineitem")[0].data
    for variant in (dataclasses.replace(plan, bitmap_only=True),
                    dataclasses.replace(plan, shuffle=("l_orderkey", 4))):
        ref, ref_aux = execute_push_plan(variant, part)
        got, aux = compile_push_plan(variant).execute(part)
        assert_tables_identical(ref, got)
        assert set(ref_aux) == set(aux)
        for k in ref_aux:
            if k == "shuffle_parts":
                for rp, bp in zip(ref_aux[k], aux[k]):
                    assert_tables_identical(rp, bp)
            else:
                np.testing.assert_array_equal(ref_aux[k], aux[k])


def test_filter_decision_log():
    """Each predicate-bearing batch records its adaptive branch choice."""
    from repro.core import executor as X
    q = Q.build_query("Q6")
    reqs = engine.plan_requests(q, CAT)
    X.reset_filter_decisions()
    engine.execute_requests(reqs, filter_gather_threshold=1.5)
    counts = X.filter_decision_counts()
    assert counts["gather"] >= 1 and counts["concat"] == 0
    X.reset_filter_decisions()
    engine.execute_requests(reqs, filter_gather_threshold=0.0)
    counts = X.filter_decision_counts()
    assert counts["concat"] >= 1 and counts["gather"] == 0
    d = X.FILTER_DECISIONS[0]
    assert d["table"] == "lineitem" and 0.0 <= d["est_selectivity"] <= 1.0
    X.reset_filter_decisions()


@pytest.mark.parametrize("qid", Q.QUERY_IDS)
@pytest.mark.parametrize("mode", engine.MODES)
def test_end_to_end_byte_identical(qid, mode):
    """Final query results agree bit-for-bit between executors, per mode."""
    q = Q.build_query(qid)
    rb = engine.run_query(q, CAT, engine.EngineConfig(
        mode=mode, executor=engine.EXECUTOR_BATCHED))
    rr = engine.run_query(q, CAT, engine.EngineConfig(
        mode=mode, executor=engine.EXECUTOR_REFERENCE))
    assert_tables_identical(rb.result, rr.result, (qid, mode))
    # scheduling outcomes don't depend on the executor either
    assert rb.n_admitted == rr.n_admitted
    assert rb.n_pushed_back == rr.n_pushed_back


def test_compiled_cost_identical():
    """CompiledPushPlan.estimate_cost memoizes the plan-level invariants
    but must reproduce plan.estimate_cost exactly, every partition."""
    for qid in Q.QUERY_IDS:
        q = Q.build_query(qid)
        for table, plan in q.plans.items():
            cplan = compile_push_plan(plan)
            assert cplan.accessed == plan.accessed_columns()
            for part in CAT.partitions_of(table):
                assert cplan.estimate_cost(part) == estimate_cost(plan, part), \
                    (qid, table, part.index)


def test_compile_memoized_per_plan():
    plan = Q.build_query("Q1").plans["lineitem"]
    assert compile_push_plan(plan) is compile_push_plan(plan)
    # a structurally-equal but distinct plan object compiles separately
    import dataclasses
    clone = dataclasses.replace(plan)
    assert compile_push_plan(clone) is not compile_push_plan(plan)


# ------------------------------------------ segment-keyed partial aggs
def _random_parts(rng, n_parts, allow_empty=True):
    """A random table split into contiguous partitions (some possibly
    empty — a filter can drain a partition, and the batch path must keep
    segment bookkeeping straight)."""
    sizes = [int(rng.integers(0 if allow_empty else 1, 400))
             for _ in range(n_parts)]
    n = sum(sizes)
    tab = {
        "k1": rng.integers(0, 5, n).astype(np.int32),
        "k2": rng.integers(0, 3, n).astype(np.int32),
        "v_f": rng.normal(size=n),
        "v_i": rng.integers(-50, 50, n).astype(np.int32),
        "x": rng.uniform(0, 100, n),
    }
    parts, at = [], 0
    for s in sizes:
        parts.append(ColumnTable({k: v[at:at + s] for k, v in tab.items()}))
        at += s
    return parts


AGGS = (("s", "sum", "v_f"), ("mn", "min", "v_i"), ("mx", "max", "v_f"),
        ("avg", "mean", "v_f"), ("cnt", "count", ""))


def _check_segmented_agg(seed, n_parts, n_keys, with_pred):
    rng = np.random.default_rng(seed)
    parts = _random_parts(rng, n_parts)
    keys = ("k1", "k2")[:n_keys]
    plan = PushPlan(
        "t", tuple(keys),
        predicate=(Col("x") < 60) if with_pred else None,
        agg=(tuple(keys), AGGS))
    _check_batch_equals_reference(plan, parts)


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 10**6), st.integers(1, 8), st.integers(0, 2),
           st.booleans())
    @settings(max_examples=25, deadline=None)
    def test_segmented_agg_property(seed, n_parts, n_keys, with_pred):
        _check_segmented_agg(seed, n_parts, n_keys, with_pred)


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("n_keys", [0, 1, 2])
@pytest.mark.parametrize("with_pred", [False, True])
def test_segmented_agg_deterministic(seed, n_keys, with_pred):
    _check_segmented_agg(seed, n_parts=1 + seed % 6, n_keys=n_keys,
                         with_pred=with_pred)


@pytest.mark.parametrize("n_keys", [0, 1])
def test_agg_then_topk(n_keys):
    """agg + top_k in one plan: the top-k must segment the agg *output*
    (rows collapsed to groups), not the filtered input rows."""
    rng = np.random.default_rng(13)
    parts = _random_parts(rng, 5)
    keys = ("k1",)[:n_keys]
    plan = PushPlan("t", tuple(keys), predicate=Col("x") < 80,
                    agg=(tuple(keys), (("s", "sum", "v_f"),)),
                    top_k=("s", 3, False))
    _check_batch_equals_reference(plan, parts)


@pytest.mark.parametrize("seed", range(4))
def test_segmented_topk(seed):
    rng = np.random.default_rng(seed)
    parts = _random_parts(rng, 5)
    plan = PushPlan("t", ("k1", "v_f"), predicate=Col("x") < 70,
                    top_k=("v_f", 7, bool(seed % 2)))
    _check_batch_equals_reference(plan, parts)


@pytest.mark.parametrize("seed", range(4))
def test_segmented_derive_project(seed):
    rng = np.random.default_rng(seed)
    parts = _random_parts(rng, 6)
    plan = PushPlan(
        "t", ("k1", "dbl"), predicate=(Col("v_i") > 0) | (Col("x") < 20),
        derive=(("dbl", ("v_f", "x"), lambda a, b: a * b + 1.0),))
    _check_batch_equals_reference(plan, parts)


def test_all_partitions_filtered_out():
    rng = np.random.default_rng(7)
    parts = _random_parts(rng, 4, allow_empty=False)
    plan = PushPlan("t", ("k1",), predicate=Col("x") > 1e9,
                    agg=(("k1",), (("s", "sum", "v_f"), ("c", "count", ""))))
    _check_batch_equals_reference(plan, parts)


def test_grouped_minmax_reduceat_matches_loop():
    """The reduceat vectorization of grouped min/max (operators.py) equals
    the per-segment loop it replaced."""
    from repro.queryproc import operators as ops
    rng = np.random.default_rng(3)
    n = 5000
    t = ColumnTable({"k": rng.integers(0, 40, n).astype(np.int32),
                     "v": rng.normal(size=n)})
    out = ops.grouped_agg(t, ["k"], {"lo": ("min", "v"), "hi": ("max", "v")})
    want_lo = [t.cols["v"][t.cols["k"] == k].min()
               for k in np.unique(t.cols["k"])]
    want_hi = [t.cols["v"][t.cols["k"] == k].max()
               for k in np.unique(t.cols["k"])]
    np.testing.assert_array_equal(out.cols["lo"], want_lo)
    np.testing.assert_array_equal(out.cols["hi"], want_hi)


# ------------------------------------------------- compiled expressions
def test_compile_expr_bitwise_equals_evaluate():
    from repro.queryproc import expressions as ex
    rng = np.random.default_rng(11)
    t = ColumnTable({"a": rng.uniform(0, 100, 4096),
                     "b": rng.integers(0, 20, 4096).astype(np.int32),
                     "c": rng.uniform(0, 100, 4096)})
    exprs = [
        (Col("a") > 30) & (Col("b").isin([2, 5, 7])),
        (Col("a") < Col("c")) | Col("b").eq(3),
        Col("a").between(10, 90) & ((Col("b") >= 4) | (Col("c") <= 50)),
    ]
    for e in exprs:
        np.testing.assert_array_equal(ex.compile_expr(e)(t.cols),
                                      ex.evaluate(e, t))


def test_compile_selectivity_equals_estimate():
    from repro.queryproc import expressions as ex
    for qid in Q.QUERY_IDS:
        q = Q.build_query(qid)
        for table, plan in q.plans.items():
            if plan.predicate is None:
                continue
            for part in CAT.partitions_of(table):
                stats = part.data.stats()
                assert (ex.compile_selectivity(plan.predicate)(stats)
                        == ex.estimate_selectivity(plan.predicate, stats)), \
                    (qid, table)


# ------------------------------------------------------ engine plumbing
def test_execute_requests_groups_by_plan():
    q = Q.build_query("Q3")
    reqs = engine.plan_requests(q, CAT)
    ref = engine.execute_requests(reqs, engine.EXECUTOR_REFERENCE)
    bat = engine.execute_requests(reqs, engine.EXECUTOR_BATCHED)
    assert set(ref) == set(bat)
    for table in ref:
        assert_tables_identical(ref[table], bat[table], table)


def test_single_partition_execute():
    plan = Q.build_query("Q6").plans["lineitem"]
    part = CAT.partitions_of("lineitem")[0].data
    ref, _aux = execute_push_plan(plan, part)
    bat, _ = compile_push_plan(plan).execute(part)
    assert_tables_identical(ref, bat)


def test_fused_pallas_matches_batched_numpy():
    """The fused Pallas kernel (predicate -> mask -> grouped agg, one pass)
    agrees with the numpy batch executor on a pushed Q1-style plan."""
    import jax.numpy as jnp
    from repro.kernels import ops as kops
    rng = np.random.default_rng(5)
    n = 6000
    ship = rng.uniform(0, 3000, n).astype(np.float32)
    flag = rng.integers(0, 3, n).astype(np.int32)
    qty = rng.uniform(1, 50, n).astype(np.float32)
    expr = Col("l_shipdate") <= 2000.0
    sums, counts = kops.fused_scan_agg(
        {"l_shipdate": jnp.asarray(ship)}, kops.compile_predicate(expr),
        jnp.asarray(flag), jnp.asarray(qty), 3, block=2048)
    parts = [ColumnTable({"l_shipdate": ship[i::2], "flag": flag[i::2],
                          "qty": qty[i::2]}) for i in range(2)]
    plan = PushPlan("t", ("flag",), predicate=expr,
                    agg=(("flag",), (("s", "sum", "qty"),
                                     ("c", "count", ""))))
    bat = compile_push_plan(plan).execute_batch(parts)
    # batch output is segment-major (partition, key): fold partials
    want_s = np.zeros(3)
    np.add.at(want_s, bat.cols["flag"], bat.cols["s"])
    want_c = np.zeros(3, np.int64)
    np.add.at(want_c, bat.cols["flag"], bat.cols["c"])
    np.testing.assert_allclose(np.asarray(sums), want_s, rtol=1e-4)
    np.testing.assert_array_equal(np.asarray(counts), want_c)
