"""Substrate layers: sharding rules, checkpointing, data pipeline,
train loop, serving."""
import os
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import CorpusQuery, PushdownDataPipeline, synth_corpus
from repro.distributed import sharding as shd
from repro.models import api
from repro.train import optimizer as opt_lib
from repro.train.checkpoint import CheckpointManager, PreemptionGuard
from repro.train.loop import TrainConfig, train


# ------------------------------------------------------------- sharding
class _FakeMesh:
    """Duck-typed mesh: spec_to_pspec only reads .shape."""
    def __init__(self, shape):
        self.shape = shape


def test_spec_to_pspec_divisibility_and_priority():
    mesh = _FakeMesh({"data": 16, "model": 16})
    # heads divisible -> heads take model, attn_seq gets nothing
    ps = shd.spec_to_pspec((32, 4096, 64, 128), ("batch", "attn_seq", "heads", None),
                           mesh, shd.BASELINE_RULES)
    assert tuple(ps) == ("data", None, "model")
    # heads NOT divisible -> attn_seq falls back to model
    ps = shd.spec_to_pspec((32, 4096, 40, 128), ("batch", "attn_seq", "heads", None),
                           mesh, shd.BASELINE_RULES)
    assert tuple(ps) == ("data", "model")
    # batch smaller than the DP axis: no sharding (divisibility guard)
    ps = shd.spec_to_pspec((8, 4096, 64, 128), ("batch", "attn_seq", "heads", None),
                           mesh, shd.BASELINE_RULES)
    assert tuple(ps) == (None, None, "model")
    # kv_heads too small -> kv head_dim takes model under INFERENCE rules
    ps = shd.spec_to_pspec((8192, 8, 128), ("embed", "kv_heads", "kv_hd"),
                           mesh, shd.INFERENCE_RULES)
    assert tuple(ps) == (None, None, "model")
    # no mesh axis used twice
    ps = shd.spec_to_pspec((64, 8192, 1408), ("experts", "embed", "mlp"),
                           mesh, shd.BASELINE_RULES)
    flat = [a for a in ps if a]
    assert len(flat) == len(set(flat))


def test_pspec_multi_axis_batch():
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    ps = shd.spec_to_pspec((256, 4096), ("batch", None), mesh,
                           shd.BASELINE_RULES)
    assert ps[0] == ("pod", "data")
    # batch=1 (long_500k): falls through to replication
    ps = shd.spec_to_pspec((1, 4096), ("batch", None), mesh,
                           shd.BASELINE_RULES)
    assert tuple(ps) == ()


# ----------------------------------------------------------- checkpoints
def _tiny_state(seed=0):
    cfg = get_config("olmo-1b", reduced=True)
    params = api.init_params(cfg, jax.random.PRNGKey(seed))
    return cfg, (params, opt_lib.init(params))


def test_checkpoint_roundtrip_and_keep_k():
    cfg, state = _tiny_state()
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for step in (1, 2, 3):
            mgr.save(step, state)
        assert mgr.all_steps() == [2, 3]  # keep-k pruning
        restored, step = mgr.restore(state)
        assert step == 3
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
            assert a.dtype == b.dtype  # bf16 survives the npz roundtrip


def test_checkpoint_async_and_atomic():
    _, state = _tiny_state()
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=3)
        mgr.save_async(5, state)
        mgr.wait()
        assert mgr.latest_step() == 5
        # no tmp debris after a successful publish
        assert not list(Path(d).glob(".step_*"))


def test_checkpoint_elastic_restore_new_sharding():
    """Restore lays arrays onto a different device layout (elastic)."""
    _, state = _tiny_state()
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, state)
        sh = jax.tree.map(
            lambda _: jax.sharding.SingleDeviceSharding(jax.devices()[0]),
            state)
        restored, _ = mgr.restore(state, shardings=sh)
        for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(state)):
            assert isinstance(a.sharding, jax.sharding.SingleDeviceSharding)
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_preemption_guard():
    calls = []
    with PreemptionGuard(lambda: calls.append(1)) as g:
        os.kill(os.getpid(), 15)  # SIGTERM
        import time
        for _ in range(100):
            if g.fired:
                break
            time.sleep(0.01)
    assert g.fired and calls == [1]


# --------------------------------------------------------- data pipeline
def test_pipeline_determinism_and_shapes():
    cfg = get_config("olmo-1b", reduced=True)
    corpus = synth_corpus(num_partitions=4, docs_per_part=64, doc_len=128,
                          vocab=cfg.vocab_size)
    q = CorpusQuery(min_quality=0.4, seq_len=64, global_batch=8, accum=2,
                    dp_ranks=2)
    a = [next(PushdownDataPipeline(corpus, q, seed=7)) for _ in range(1)]
    b = [next(PushdownDataPipeline(corpus, q, seed=7)) for _ in range(1)]
    np.testing.assert_array_equal(a[0]["tokens"], b[0]["tokens"])
    assert a[0]["tokens"].shape == (2, 4, 64)  # (accum, mb, S)


def test_pipeline_filters_quality():
    corpus = synth_corpus(num_partitions=2, docs_per_part=128, doc_len=64)
    q = CorpusQuery(min_quality=0.9, seq_len=32, global_batch=4, dp_ranks=1)
    pipe = PushdownDataPipeline(corpus, q)
    batch = next(pipe)
    kept_docs = sum(int((p.quality >= 0.9).sum()) for p in corpus)
    assert kept_docs < 40  # the filter is actually selective
    assert pipe.stats()["admitted"] + pipe.stats()["pushed_back"] == 2


def test_pipeline_rank_alignment():
    """Shuffle-to-rank: a document's tokens land on its hash rank."""
    from repro.queryproc.operators import hash_partition_ids
    corpus = synth_corpus(num_partitions=2, docs_per_part=64, doc_len=32)
    q = CorpusQuery(min_quality=0.0, seq_len=32, global_batch=4, accum=1,
                    dp_ranks=2)
    pipe = PushdownDataPipeline(corpus, q)
    batch = next(pipe)["tokens"]  # (1, 4, 32): rows 0-1 rank0, 2-3 rank1
    part = corpus[0]
    ranks = hash_partition_ids(part.doc_id.astype(np.int64), 2)
    doc0 = part.tokens[0]
    rows = batch.reshape(-1, 32)
    hits = [i for i, r in enumerate(rows) if np.array_equal(r, doc0)]
    if hits:  # doc0 made it into the first batch
        rank_rows = {0: (0, 1), 1: (2, 3)}[ranks[0]]
        assert all(h in rank_rows for h in hits)


# ------------------------------------------------------------ train loop
def test_train_resume_exact():
    cfg = get_config("olmo-1b", reduced=True)
    corpus = synth_corpus(num_partitions=2, docs_per_part=64, doc_len=128,
                          vocab=cfg.vocab_size)
    q = CorpusQuery(min_quality=0.2, seq_len=64, global_batch=4, accum=2,
                    dp_ranks=1)
    opt = opt_lib.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=8)
    with tempfile.TemporaryDirectory() as d:
        t1 = TrainConfig(steps=8, ckpt_every=100, ckpt_dir=None, log_every=1,
                         opt=opt)
        full = train(cfg, iter(PushdownDataPipeline(corpus, q, seed=3)), t1)
        t2 = TrainConfig(steps=4, ckpt_every=4, ckpt_dir=d, log_every=1, opt=opt)
        train(cfg, iter(PushdownDataPipeline(corpus, q, seed=3)), t2)
        t3 = TrainConfig(steps=8, ckpt_every=100, ckpt_dir=d, log_every=1,
                         opt=opt)
        resumed = train(cfg, iter(PushdownDataPipeline(corpus, q, seed=3)), t3)
    # deterministic stream + exact state restore => identical final loss
    assert resumed["final_step"] == full["final_step"] == 8
    a = full["history"][-1]["loss"]
    b = resumed["history"][-1]["loss"]
    assert abs(a - b) < 5e-2, (a, b)


def test_loss_decreases():
    cfg = get_config("olmo-1b", reduced=True)
    corpus = synth_corpus(num_partitions=2, docs_per_part=32, doc_len=128,
                          vocab=cfg.vocab_size, seed=1)
    q = CorpusQuery(min_quality=0.0, seq_len=64, global_batch=4, accum=1,
                    dp_ranks=1)
    out = train(cfg, iter(PushdownDataPipeline(corpus, q)),
                TrainConfig(steps=30, ckpt_dir=None, log_every=5,
                            opt=opt_lib.AdamWConfig(lr=3e-3, warmup_steps=5,
                                                    total_steps=30)))
    first, last = out["history"][0]["loss"], out["history"][-1]["loss"]
    assert last < first  # tiny model memorizes a tiny corpus
