"""Cost-based frontier selection: the property harness.

Three families of guarantees:

1. **Any cut is correct** — for random frontier cuts on all 15 TPC-H
   queries, results equal the maximal-frontier reference. Columns of
   exact dtype must match *bitwise*; float columns are compared at
   1e-9 relative tolerance, because a cut below an absorbed aggregate
   legitimately changes float summation order (the maximal path merges
   per-partition partials, a shallow cut sums the merged raw rows —
   non-associative addition, same math). Cuts that unabsorb no aggregate
   are asserted fully bitwise.
2. **The chosen cut is optimal** — ``compile_query_costed`` picks the
   candidate whose estimated cost is <= every enumerated candidate's,
   and the k=0 candidate (the raw-projection baseline) is always among
   them.
3. **Goldens** — the exact set of queries where the cost-based cut
   differs from maximal, with their frontier signatures (Q18-style
   high-NDV group keys cut below the agg; Q19 carries a bitmap-lowered
   multi-table predicate), plus the real net-byte win the cheaper cuts
   deliver.

Property tests use hypothesis when present; a deterministic seed sweep
covers the same invariants when it is absent.
"""
import math
import zlib

import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:  # optional dependency — see pyproject.toml [test]
    HAVE_HYPOTHESIS = False

from repro.compiler import (compile_ir, compile_query_costed,
                            compile_query_detailed, ir, multitable, splitter,
                            tpch_ir)
from repro.core import engine
from repro.core.cost import CardinalityCorrector, StorageResources, cut_score
from repro.queryproc import expressions as ex
from repro.queryproc import queries as Q
from repro.queryproc import tpch

CAT = tpch.build_catalog(sf=1.0, num_nodes=2, rows_per_partition=4_000)
CFG = engine.EngineConfig(mode="eager")

_REFERENCE = {}  # qid -> maximal-frontier result (computed once)


def reference_result(qid):
    if qid not in _REFERENCE:
        _REFERENCE[qid] = engine.run_query(
            compile_query_detailed(qid).query, CAT, CFG).result
    return _REFERENCE[qid]


def assert_results_match(ref, got, ctx="", bitwise=True):
    """Schema + row multiset equality; exact-dtype columns always
    bitwise, float columns bitwise only when ``bitwise`` (else 1e-9)."""
    assert set(ref.columns) == set(got.columns), (ctx, ref.columns,
                                                  got.columns)
    assert len(ref) == len(got), (ctx, len(ref), len(got))
    if len(ref) == 0:
        return
    cols = sorted(ref.columns)
    is_float = {c: np.asarray(ref.cols[c]).dtype.kind in "fc" for c in cols}
    order = [c for c in cols if is_float[c]] + \
            [c for c in cols if not is_float[c]]

    def row_order(t):
        return np.lexsort(tuple(np.asarray(t.cols[c]) for c in order))

    ia, ib = row_order(ref), row_order(got)
    for c in cols:
        x, y = np.asarray(ref.cols[c])[ia], np.asarray(got.cols[c])[ib]
        if bitwise:
            assert x.dtype == y.dtype, (ctx, c, x.dtype, y.dtype)
            assert np.array_equal(x, y, equal_nan=True), (ctx, c)
        elif is_float[c] or x.dtype != y.dtype:
            # an unabsorbed aggregate changes float summation order, and
            # merging count partials via `sum` widens int64 -> float64 —
            # value-equal either way
            assert np.allclose(x.astype(np.float64), y.astype(np.float64),
                               rtol=1e-9, atol=1e-12), (ctx, c)
        else:
            assert np.array_equal(x, y, equal_nan=True), (ctx, c)


# ----------------------------------------- random cuts stay correct
def _random_cuts(sp: splitter.SplitResult, seed: int):
    rng = np.random.default_rng(seed)
    return {t: int(rng.integers(0, sp.max_cut[t] + 1)) for t in sp.plans}


def _check_random_cut(qid: str, seed: int):
    root = tpch_ir.build_ir(qid)
    sp = splitter.split(root)
    cuts = _random_cuts(sp, seed)
    cq = compile_ir(root, qid, cuts=cuts)
    # the cut really took: every plan is the enumerated candidate
    for t, k in cuts.items():
        assert cq.split.cuts[t] == k
        assert cq.plans[t] == sp.candidates[t][k], (qid, t, k)
    got = engine.run_query(cq.query, CAT, CFG).result
    # bitwise unless the cut unabsorbed an aggregate (float merge order)
    agg_moved = any(sp.candidates[t][sp.max_cut[t]].agg is not None
                    and cuts[t] < sp.max_cut[t] for t in cuts)
    assert_results_match(reference_result(qid), got, (qid, cuts),
                         bitwise=not agg_moved)


@pytest.mark.parametrize("qid", Q.QUERY_IDS)
def test_random_cut_matches_maximal_reference(qid):
    _check_random_cut(qid, seed=zlib.crc32(qid.encode()))


if HAVE_HYPOTHESIS:
    @given(st.sampled_from(Q.QUERY_IDS), st.integers(0, 10 ** 6))
    @settings(max_examples=15, deadline=None)
    def test_random_cut_property(qid, seed):
        _check_random_cut(qid, seed)


@pytest.mark.parametrize("seed", range(3))
def test_random_cut_deterministic_sweep(seed):
    for qid in ("Q1", "Q6", "Q18", "Q19"):
        _check_random_cut(qid, seed=seed * 1000 + 11)


def test_all_zero_cut_is_raw_projection_baseline():
    """k=0 everywhere: nothing is pushed but the accessed-column
    projection; the residual replays the whole chain. Still equal."""
    for qid in ("Q1", "Q12", "Q22"):
        root = tpch_ir.build_ir(qid)
        sp = splitter.split(root)
        cq = compile_ir(root, qid, cuts={t: 0 for t in sp.plans})
        for plan in cq.plans.values():
            assert plan.predicate is None and plan.agg is None \
                and plan.top_k is None and not plan.derive
        got = engine.run_query(cq.query, CAT, CFG).result
        agg_somewhere = any(sp.candidates[t][sp.max_cut[t]].agg is not None
                            for t in sp.plans)
        assert_results_match(reference_result(qid), got, qid,
                             bitwise=not agg_somewhere)


def test_shallow_cut_does_not_leak_replay_columns():
    """A shallow cut ships extra columns so the residual can replay the
    chain (here: l_quantity for the filter). The replayed chain must be
    projected back to the maximal schema — in a Join-rooted query those
    extras would otherwise leak into the final result."""
    from repro.queryproc.expressions import Col
    li = ir.Filter(ir.Scan("lineitem", ("l_orderkey",)),
                   Col("l_quantity") < 10)
    od = ir.Scan("orders", ("o_orderkey",))
    root = ir.Join(li, od, "l_orderkey", "o_orderkey")
    ref = engine.run_query(compile_ir(root, "LEAK").query, CAT, CFG).result
    cut_q = compile_ir(root, "LEAK", cuts={"lineitem": 0})
    # the shallow plan itself must ship the filter's input...
    assert "l_quantity" in cut_q.plans["lineitem"].columns
    got = engine.run_query(cut_q.query, CAT, CFG).result
    # ...but the result schema must not contain it
    assert ref.columns == got.columns
    assert_results_match(ref, got, "leak", bitwise=True)


def test_cut_out_of_range_rejected():
    root = tpch_ir.build_ir("Q6")
    with pytest.raises(splitter.CompileError):
        splitter.split(root, cuts={"lineitem": 99})
    with pytest.raises(splitter.CompileError):
        splitter.split(root, cuts={"nosuchtable": 0})


# ----------------------------------------- chosen cut is cost-minimal
@pytest.mark.parametrize("qid", Q.QUERY_IDS)
def test_chosen_cut_minimizes_estimated_cost(qid):
    cq = compile_query_costed(qid, CAT)
    assert cq.cut_report, qid
    for choice in cq.cut_report:
        assert len(choice.scores) == choice.maximal + 1
        best = choice.scores[choice.chosen]
        assert best <= min(choice.scores) + 1e-12, (qid, choice)
        # the raw-projection baseline is always candidate k=0
        assert choice.signatures[0].startswith("scan"), (qid, choice)
        assert "agg" not in choice.signatures[0]


def test_cut_score_charges_cpu_only_for_operator_work():
    from repro.core.cost import RequestCost
    res = StorageResources()
    c = RequestCost(s_in=10_000, s_out=5_000, compute_in=10_000)
    bare = cut_score(c, res, has_operator_work=False)
    work = cut_score(c, res, has_operator_work=True)
    assert bare == pytest.approx(5_000 / res.stream_bw)
    assert work == pytest.approx(bare + c.t_compute(res))
    # power below one core's share slows the slot itself: work costlier,
    # ship time equal (per-slot stream share is fixed, §3.3)
    weak = cut_score(c, res.with_power(0.01), has_operator_work=True)
    assert weak > work
    assert cut_score(c, res.with_power(0.01), has_operator_work=False) \
        == pytest.approx(bare)


# --------------------------------------------------------- golden cuts
# Queries where the cost-based cut differs from the maximal frontier at
# the pinned catalog (sf=1, 2 nodes, 4000-row partitions), with the full
# chosen frontier signature. Everything not listed compiles identically
# to the maximal frontier.
COSTED_GOLDEN = {
    # high-NDV group key (l_orderkey ~ unique per partition): partial agg
    # ships ~1 row per input row and burns storage CPU — cut at the scan
    "Q18": {"lineitem": "scan", "orders": "scan"},
    # derived flag costed at 8 B/row vs 2 narrow date inputs: the model
    # prefers shipping the raw columns (feedback flips this back, see
    # test_corrected_chooser_* below)
    "Q4": {"lineitem": "scan", "orders": "scan+filter"},
    # 25-row dimension: running the filter at storage costs more CPU than
    # the handful of saved bytes — nation itself stays a bare scan. But the
    # region restriction's *value domain* (n_nationkey ∈ region-2 nations)
    # propagates over the join edge and the c_nationkey == s_nationkey
    # equality into In-filters on customer and supplier (multitable
    # domain derivation), so both now push a filter stage
    "Q5": {"customer": "scan+filter", "lineitem": "scan+derive",
           "nation": "scan", "orders": "scan+filter",
           "supplier": "scan+filter"},
    # same derivation: region-1 nations narrow customer via the
    # c_nationkey = n_nationkey join; the p_type-restricted part keys
    # narrow lineitem (sideways information passing as an In-list)
    "Q8": {"customer": "scan+filter", "lineitem": "scan+filter+derive",
           "nation": "scan", "orders": "scan+filter",
           "part": "scan+filter", "supplier": "scan"},
    # customer's mktsegment survivors narrow orders by o_custkey (the
    # signature is unchanged — the In joins o_orderdate as a conjunct —
    # but pinning it here keeps Q3 in the bitwise-identity sweep)
    "Q3": {"customer": "scan+filter", "lineitem": "scan+filter+derive",
           "orders": "scan+filter"},
    # the brand/container-filtered part keys narrow lineitem at its scan;
    # born at the shared join itself, so both consumers (the avg_qty
    # aggregate and the rejoin) still see identical rows
    "Q17": {"lineitem": "scan+filter", "part": "scan+filter"},
    # multi-table two-nation OR lowered onto both sides as conjuncts
    "Q7": {"customer": "scan+filter", "lineitem": "scan+filter+derive",
           "orders": "scan", "supplier": "scan+filter"},
    # multi-table join predicate: part side lowered as a conjunct,
    # lineitem side as the §4.2 bitmap exchange
    "Q19": {"lineitem": "scan+filter+bitmap+derive", "part": "scan+filter"},
}


def _golden_diff(qid, got, want):
    lines = [f"{qid}: cost-based frontier drifted from the golden —"]
    for t in sorted(set(got) | set(want)):
        g, w = got.get(t, "<missing>"), want.get(t, "<missing>")
        mark = "  " if g == w else "->"
        lines.append(f"  {mark} {t}: golden={w!r} got={g!r}")
    lines.append("If the chooser/cost model changed intentionally, "
                 "re-pin COSTED_GOLDEN (tests/test_cost_split.py).")
    return "\n".join(lines)


@pytest.mark.parametrize("qid", Q.QUERY_IDS)
def test_costed_golden_frontiers(qid):
    cq = compile_query_costed(qid, CAT)
    got = cq.frontier_signature()
    want = COSTED_GOLDEN.get(qid, compile_query_detailed(
        qid).frontier_signature())
    assert got == want, _golden_diff(qid, got, want)


def test_golden_set_covers_expected_phenomena():
    """The golden set must contain a below-the-agg cut on a high-NDV
    group key and at least one bitmap-lowered multi-table predicate."""
    assert COSTED_GOLDEN["Q18"]["lineitem"] == "scan"
    assert any("bitmap" in sig for sigs in COSTED_GOLDEN.values()
               for sig in sigs.values())
    # and the bitmap really is a lowered *multi-table* predicate
    cq = compile_query_costed("Q19", CAT)
    li = next(c for c in cq.cut_report if c.table == "lineitem")
    assert li.bitmap and li.lowered is not None
    assert cq.plans["lineitem"].bitmap_only


@pytest.mark.parametrize("qid", sorted(COSTED_GOLDEN))
def test_costed_results_bitwise_identical(qid):
    """Every query whose cost-based cut differs still returns bitwise the
    maximal frontier's result: lowered implied predicates only remove
    join-doomed rows (order preserved), Q18's sum_qty sums integers
    exactly, Q4's derive replays elementwise."""
    cq = compile_query_costed(qid, CAT)
    got = engine.run_query(cq.query, CAT, CFG).result
    assert_results_match(reference_result(qid), got, qid, bitwise=True)


def test_costed_ships_fewer_net_bytes():
    """The acceptance headline: cost-based cuts measurably ship fewer
    real net bytes than the maximal frontier on the lowered queries."""
    savings = {}
    for qid in ("Q7", "Q19"):
        rc = engine.run_query(compile_query_costed(qid, CAT).query, CAT, CFG)
        rm = engine.run_query(compile_query_detailed(qid).query, CAT, CFG)
        assert rc.real_net_bytes < rm.real_net_bytes, (
            qid, rc.real_net_bytes, rm.real_net_bytes)
        savings[qid] = 1 - rc.real_net_bytes / rm.real_net_bytes
    # Q19's part disjunction is highly selective: a >20% traffic cut
    assert savings["Q19"] > 0.2, savings


# ------------------------------------------------- multi-table lowering
def test_implied_predicate_derivation():
    from repro.queryproc.expressions import Col
    owned = {"a", "b"}
    p = (Col("a") > 1) & (Col("x") > 2)
    got = multitable.implied_predicate(p, owned)
    assert repr(got) == repr(Col("a") > 1)
    # Or requires both branches to imply
    assert multitable.implied_predicate(
        (Col("a") > 1) | (Col("x") > 2), owned) is None
    got = multitable.implied_predicate(
        ((Col("a") > 1) & (Col("x") > 2)) | (Col("b") > 3), owned)
    assert repr(got) == repr((Col("a") > 1) | (Col("b") > 3))
    # col-col within one table qualifies, across tables does not
    assert multitable.implied_predicate(
        Col("a").eq(Col("b")), owned) is not None
    assert multitable.implied_predicate(
        Col("a").eq(Col("x")), owned) is None


def test_lowering_soundness_walk_blocks_unsafe_paths():
    from repro.queryproc.expressions import Col
    res = StorageResources()
    # aggregate between scan and the multi-table filter: removing rows
    # would change the aggregate — must not lower onto lineitem
    li = ir.Aggregate(ir.Scan("lineitem", ()), ("l_orderkey",),
                      (("s", "sum", "l_quantity"),))
    od = ir.Scan("orders", ("o_orderkey", "o_custkey"))
    j = ir.Join(li, od, "l_orderkey", "o_orderkey")
    f = ir.Filter(j, (Col("s") > 5) & (Col("o_custkey") < 3)
                  & (Col("l_orderkey") < 100))
    root2, lows = multitable.lower(f, CAT, res)
    assert all(lw.table != "lineitem" for lw in lows)
    # orders side is safe and gets its conjunct
    assert any(lw.table == "orders" for lw in lows)


def test_lowering_preserves_q17_shared_subtree():
    """Q17's qty_thresh filter references a derived column through a shared
    join — the multi-table walk must lower nothing from it. The *domain*
    derivation still narrows lineitem: the In over the filtered part keys
    is born at the shared join itself (rows outside it produce no join
    output), so it is sound below the share point."""
    root = tpch_ir.build_ir("Q17")
    root2, lows = multitable.lower(root, CAT, StorageResources())
    assert [lw.table for lw in lows] == ["lineitem"]
    assert lows[0].source == "domain[l_partkey]"
    assert isinstance(lows[0].predicate, ex.In)
    assert lows[0].predicate.col.name == "l_partkey"
    assert root2 is not root


def test_bitmap_lowered_frontier_ships_exchange_verdicts():
    """The §4.2 exchange contract: a bitmap-lowered frontier's shipped
    per-partition bitmaps unpack to exactly the pushed predicate's
    verdicts over the raw rows — what the compute layer combines with
    the other table's verdicts instead of re-evaluating its conjunct."""
    from repro.core.bitmap import merged_verdicts
    from repro.core.executor import compile_push_plan
    from repro.queryproc import expressions as ex

    cq = compile_query_costed("Q19", CAT)
    plan = cq.plans["lineitem"]
    assert plan.bitmap_only
    cplan = compile_push_plan(plan)
    parts = [p.data for p in CAT.partitions_of("lineitem")[:5]]
    _tabs, aux = cplan.execute_batch_parts(parts)
    bitmaps = [a["bitmap"] for a in aux]
    got = merged_verdicts(bitmaps, [len(p) for p in parts])
    pred_fn = ex.compile_expr(plan.predicate)
    want = np.concatenate([pred_fn(dict(p.cols)) for p in parts])
    np.testing.assert_array_equal(got, want)
    # and the verdicts imply the lowered conjunct (the implied predicate
    # is a consequence of the full pushed predicate)
    li = next(c for c in cq.cut_report if c.table == "lineitem")
    assert li.lowered is not None


def test_exchange_scoring_boundary():
    res = StorageResources()
    # high-selectivity single-column conjunct: bitmap pays (Q19 lineitem)
    assert multitable.exchange_pays(0.8, 1, res)
    # highly selective dimension restriction: conjunct pushdown (Q19 part)
    assert not multitable.exchange_pays(0.003, 3, res)


# ------------------------------------- corrected chooser converges cuts
def test_corrected_chooser_flips_q18_back_to_partial_agg():
    """The model overestimates Q18's partial-agg output (8 B/value vs the
    real int32 keys + near-unique groups); uncorrected it cuts at the
    scan. After observing real bytes from maximal-frontier runs, the
    corrected chooser flips the cut back — measured truth wins."""
    corr = CardinalityCorrector()
    cfg = engine.EngineConfig(mode="eager", corrector=corr)
    for _ in range(2):
        engine.run_query(Q.build_query("Q18"), CAT, cfg)
        engine.run_query(Q.build_query("Q4"), CAT, cfg)
    assert compile_query_costed(
        "Q18", CAT).frontier_signature()["lineitem"] == "scan"
    corrected = compile_query_costed("Q18", CAT, corrector=corr)
    assert corrected.frontier_signature()["lineitem"] == "scan+agg"
    # Q4's derive flips back too
    corrected4 = compile_query_costed("Q4", CAT, corrector=corr)
    assert corrected4.frontier_signature()["lineitem"] == "scan+derive"
    # and the corrected compile still returns identical bytes
    got = engine.run_query(corrected.query, CAT, CFG).result
    assert_results_match(reference_result("Q18"), got, "Q18-corrected")
