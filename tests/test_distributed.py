"""Multi-device behavior (shard_map collectives, step lowering on a real
mesh). jax locks the device count at first init, so these run in a
subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8."""
import subprocess
import sys
import textwrap

import pytest


def _run(code: str):
    prog = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=8'\n"
            + textwrap.dedent(code))
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=560,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root",
                            # forced-host mesh: never probe for a TPU (the
                            # libtpu GCP-metadata probe hangs off-cloud)
                            "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_expert_all_to_all_roundtrip():
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.collectives import (expert_all_to_all_dispatch,
                                               expert_all_to_all_combine)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    E, C, d = 8, 16, 32
    x = jnp.arange(E * C * d, dtype=jnp.float32).reshape(E, C, d)
    disp = expert_all_to_all_dispatch(x, mesh, "model")
    back = expert_all_to_all_combine(disp, mesh, "model")
    np.testing.assert_allclose(np.asarray(back), np.asarray(x))
    print("roundtrip ok", disp.shape)
    """)
    assert "roundtrip ok" in out


def test_compressed_psum_error_feedback():
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.collectives import compressed_psum
    mesh = jax.make_mesh((2, 4), ("pod", "data"))
    g = jax.random.normal(jax.random.PRNGKey(0), (16, 64))
    e = jnp.zeros_like(g)
    approx, err = compressed_psum(g, e, mesh, "pod")
    # int8 all-reduce approximates the true psum within quantization error
    true = np.asarray(g).reshape(2, 8, 64).sum(0)  # psum over pod axis
    got = np.asarray(approx).reshape(2, 8, 64)[0]
    rel = np.abs(got - true).max() / (np.abs(true).max() + 1e-9)
    assert rel < 0.05, rel
    # error feedback carries the residual
    assert float(jnp.abs(err).max()) > 0
    print("compressed psum ok", rel)
    """)
    assert "compressed psum ok" in out


def test_compressed_psum_n1_error_feedback():
    """The n==1 fast path must fold the carried error into the estimate
    (grad + err), matching the shard_map path's conservation invariant
    approx + sum(new_err) == sum(g + e) — the old `return grad, zeros`
    silently dropped the feedback and biased the long-run average."""
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.collectives import compressed_psum
    mesh1 = jax.make_mesh((1, 8), ("pod", "data"))
    g = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
    e = 0.1 * jax.random.normal(jax.random.PRNGKey(2), (8, 64))
    approx, err = compressed_psum(g, e, mesh1, "pod")
    # n=1: nothing to reduce, but the carried error must not vanish
    np.testing.assert_allclose(np.asarray(approx), np.asarray(g + e),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(err), 0.0)
    # same conservation the multi-shard path provides: each shard's
    # approx + its own new_err reconstructs its g+e contribution exactly
    mesh8 = jax.make_mesh((8, 1), ("pod", "data"))
    a8, e8 = compressed_psum(g, e, mesh8, "pod")
    v = np.asarray(g + e).reshape(8, 1, 64)
    tot = v.sum(0)
    rec = np.asarray(a8).reshape(8, 1, 64) + 0  # per-shard psum estimate
    # sum over shards of (v_i - q_i*scale) == sum v_i - approx, so
    # approx + sum(new_err) == sum(g+e) up to float assoc
    np.testing.assert_allclose(
        rec[0] + np.asarray(e8).reshape(8, 1, 64).sum(0), tot,
        rtol=1e-4, atol=1e-4)
    print("n1 feedback ok")
    """)
    assert "n1 feedback ok" in out


@pytest.mark.parametrize("arch,shape", [("olmo-1b", "train_4k"),
                                        ("qwen2-moe-a2.7b", "decode_32k"),
                                        ("mamba2-2.7b", "long_500k")])
def test_steps_lower_on_small_mesh(arch, shape):
    """The production step builders lower+compile on a small (4,2) mesh
    with REDUCED configs (full configs are the dry-run's job)."""
    out = _run(f"""
    import jax
    import dataclasses
    from repro.configs import get_config, get_shape
    from repro.launch import steps
    cfg = get_config("{arch}", reduced=True)
    shape = dataclasses.replace(get_shape("{shape}"), global_batch=8,
                                seq_len=256, accum=2)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    b = steps.build(cfg, shape, mesh)
    with mesh:
        c = b.lower().compile()
    from repro.launch.analysis import cost_summary  # list/dict-safe
    print("compiled", cost_summary(c)["flops"] > 0)
    """)
    assert "compiled True" in out


def test_dryrun_cell_subprocess():
    """One REAL dry-run cell (full config, 512 devices) exercises the
    actual deliverable path end to end."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "olmo-1b",
         "--shape", "decode_32k", "--mesh", "single", "--force",
         "--out", "/tmp/dryrun_test"],
        capture_output=True, text=True, timeout=560,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, r.stdout + r.stderr
    assert "0 failures" in r.stdout
